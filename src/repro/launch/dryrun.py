import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination: build the step
function (train_step / prefill / serve_step per the shape's kind), attach
the production shardings, ``.lower()`` over ShapeDtypeStruct stand-ins (no
allocation), ``.compile()``, and record ``memory_analysis()`` (proves it
fits 16 GB/chip), ``cost_analysis()`` (FLOPs/bytes for §Roofline), and the
collective schedule parsed from the compiled HLO (collective bytes are not
in cost_analysis).

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first init. Do not move it; do not set it globally.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh single,multi --out results/dryrun.json
"""

import argparse
import json
import math
import re
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, ASSIGNED, get_config
from ..models.model import build_model
from ..sharding.specs import ShardingRules
from ..sharding.runtime import activation_sharding
from ..training.optimizer import AdamWConfig
from ..training.train_step import init_train_state, make_train_step
from .mesh import make_production_mesh
from .shapes import SHAPES, InputShape, input_specs

from jax.sharding import NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Sum per-device collective buffer bytes and ring-moved bytes per op
    kind from the (SPMD-partitioned, per-device-shaped) compiled HLO."""
    per_kind: dict[str, dict[str, float]] = {}
    moved_total = 0.0
    buffer_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind, start = m.group(1), m.group(2), m.group(3)
        buf = _shape_bytes(shape_txt)
        if buf == 0:
            continue
        gm = _GROUP_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gl = _GROUP_LIST_RE.search(line)
            n = len(gl.group(1).split(",")) if gl else 16
        n = max(2, n)
        # ring-algorithm bytes crossing each device's link
        if kind == "all-gather":
            moved = buf * (n - 1) / n
        elif kind == "all-reduce":
            moved = 2.0 * buf * (n - 1) / n
        elif kind == "reduce-scatter":
            moved = buf * (n - 1)            # buf = per-device output shard
        elif kind == "all-to-all":
            moved = buf * (n - 1) / n
        else:                                 # collective-permute
            moved = buf
        d = per_kind.setdefault(kind, {"count": 0, "buffer_bytes": 0.0,
                                       "moved_bytes": 0.0})
        d["count"] += 1
        d["buffer_bytes"] += buf
        d["moved_bytes"] += moved
        buffer_total += buf
        moved_total += moved
    return {"per_kind": per_kind, "buffer_bytes": buffer_total,
            "moved_bytes": moved_total}


# --------------------------------------------------------------------------
# Step builders
# --------------------------------------------------------------------------

def _opt_cfg(cfg) -> AdamWConfig:
    # bf16 optimizer moments for the ≥200B-param archs (DESIGN.md §5):
    # f32 m+v for a 400B model is 3.2 TB — bf16 halves it below the
    # 16 GB/chip line at 256-512 chips.
    big = cfg.param_count() > 2e11
    return AdamWConfig(lr=1e-4, state_dtype=jnp.bfloat16 if big else None)


def build_lowering(arch: str, shape_name: str, mesh, fsdp_over_pod=True):
    """Returns (lowered, meta) for one (arch, shape, mesh)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    rules = ShardingRules(mesh, cfg, fsdp_over_pod=fsdp_over_pod)
    spec = input_specs(cfg, shape, model)
    B = shape.global_batch

    params_shape = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    p_shard = rules.params_sharding(params_shape)
    repl = NamedSharding(mesh, P())
    # sequence-parallel activation residuals (batch→dp, seq→model)
    act = NamedSharding(mesh, P(rules.batch_spec(B), "model", None))
    # head-parallel q/k/v (§Perf cycle 1: keeps the seq↔head transition on
    # the projections, not the O(S²) attention weights)
    qkv = NamedSharding(mesh, P(rules.batch_spec(B), None, "model", None))
    # vocab-parallel lm head (§Perf cycle 6)
    logits_s = NamedSharding(mesh, P(rules.batch_spec(B), None, "model"))
    head_in = None   # §Perf cycle 7: with vocab-parallel logits the head
    # contraction tolerates seq-sharded h; forcing (dp,None,None) made XLA
    # materialize full-batch f32 (B,S,D) reshard buffers

    if spec["kind"] == "train":
        opt_cfg = _opt_cfg(cfg)
        # gradient accumulation: bound live per-device tokens to ~16k
        dp = rules._axis_size(rules.batch_spec(B) or ())
        per_dev_tokens = B // max(1, dp) * spec["tokens"].shape[1]
        micro = max(1, per_dev_tokens // 16384)
        while B % micro or (B // micro) % max(1, dp):
            micro -= 1
        step = make_train_step(model, opt_cfg, micro_steps=micro)
        state_shape = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0), opt_cfg))
        state_shard = rules.train_state_sharding(state_shape, p_shard)
        batch_struct = {"tokens": spec["tokens"], "labels": spec["labels"]}
        batch_shard = {"tokens": rules.tokens_sharding(B),
                       "labels": rules.tokens_sharding(B)}
        if "frontend" in spec:
            batch_struct["frontend"] = spec["frontend"]
            batch_shard["frontend"] = rules.frontend_sharding(B)
        key_struct = jax.eval_shape(lambda: jax.random.PRNGKey(0))
        # §Perf cycle 2: unconstrained outputs let XLA replicate the
        # lm_head/embed gradients (observed 11.7 GiB f32 buffers);
        # constrain the updated state to the input layout.
        fn = jax.jit(step, in_shardings=(state_shard, batch_shard, repl),
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
        with mesh, activation_sharding(act, qkv=qkv, logits=logits_s,
                                       head_in=head_in):
            lowered = fn.lower(state_shape, batch_struct, key_struct)
        tokens = B * spec["tokens"].shape[1]
        model_flops = 6.0 * cfg.active_param_count() * tokens
        # XLA cost_analysis counts while-loop bodies ONCE (see
        # benchmarks/roofline.py): record the analytic body-trip product so
        # HLO numbers can be scaled back to per-step totals.
        return lowered, {"tokens": tokens, "model_flops": model_flops,
                         "loop_trips": micro * cfg.n_layers,
                         "micro_steps": micro}

    if spec["kind"] == "prefill":
        slots = spec["slots"]
        has_fe = "frontend" in spec

        # serving prefill returns only the anchor logits (last position) —
        # XLA then DCEs the (B, S, V) lm-head matmul down to one position
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(
                B, slots,
                enc_frames=(cfg.n_frontend_tokens
                            if cfg.arch_type == "encdec" else 0)))
        c_shard = rules.cache_sharding(cache_struct, B)
        if has_fe:
            def fn_(params, tokens, frontend):
                logits, cache = model.prefill(params, tokens, slots,
                                              frontend=frontend, chunk=1024,
                                              cache_shardings=c_shard)
                return logits[:, -1, :], cache
            args = (params_shape, spec["tokens"], spec["frontend"])
            shards = (p_shard, rules.tokens_sharding(B),
                      rules.frontend_sharding(B))
        else:
            def fn_(params, tokens):
                logits, cache = model.prefill(params, tokens, slots,
                                              chunk=1024,
                                              cache_shardings=c_shard)
                return logits[:, -1, :], cache
            args = (params_shape, spec["tokens"])
            shards = (p_shard, rules.tokens_sharding(B))
        fn = jax.jit(fn_, in_shardings=shards)
        with mesh, activation_sharding(act, qkv=qkv):
            lowered = fn.lower(*args)
        tokens = B * shape.seq_len
        model_flops = 2.0 * cfg.active_param_count() * tokens
        chunks = max(1, shape.seq_len // 1024)
        return lowered, {"tokens": tokens, "model_flops": model_flops,
                         "loop_trips": chunks * cfg.n_layers,
                         "hlo_body_copies": 2}

    # decode
    window = spec["window"]
    cache_struct = spec["cache"]
    cache_shard = rules.cache_sharding(cache_struct, B)

    def fn_(params, token, cache, pos):
        # production serving waves are position-aligned → uniform_pos lowers
        # the cache write to dynamic_update_slice (GSPMD-friendly)
        return model.decode_step(params, token, cache, pos, window=window,
                                 uniform_pos=True)

    fn = jax.jit(fn_, in_shardings=(p_shard, rules.vector_sharding(B),
                                    cache_shard, rules.vector_sharding(B)),
                 out_shardings=(None, cache_shard),
                 donate_argnums=(2,))
    with mesh:
        lowered = fn.lower(params_shape, spec["token"], cache_struct,
                           spec["pos"])
    tokens = B
    model_flops = 2.0 * cfg.active_param_count() * tokens
    return lowered, {"tokens": tokens, "model_flops": model_flops,
                     "loop_trips": cfg.n_layers}


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, mesh_kind: str,
            keep_hlo: bool = False) -> dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = math.prod(mesh.shape.values())
    row: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "devices": n_dev, "ok": False}
    t0 = time.time()
    try:
        lowered, meta = build_lowering(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        row.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            tokens=meta["tokens"],
            model_flops=meta["model_flops"],
            loop_trips=meta.get("loop_trips", 1),
            micro_steps=meta.get("micro_steps", 1),
            hlo_body_copies=meta.get("hlo_body_copies", 1),
            flops_per_device=float(ca.get("flops", 0.0)),
            bytes_per_device=float(ca.get("bytes accessed", 0.0)),
            collectives=coll,
            memory={
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                           + ma.output_size_in_bytes
                                           + ma.temp_size_in_bytes
                                           - ma.alias_size_in_bytes),
            },
        )
        if keep_hlo:
            row["hlo_len"] = len(hlo)
    except Exception as e:  # a failure here is a bug in the system
        row["error"] = f"{type(e).__name__}: {e}"[:2000]
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="comma list or 'all' (assigned archs)")
    ap.add_argument("--shape", default="all",
                    help=f"comma list or 'all' ({','.join(SHAPES)})")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                row = run_one(arch, shape, mesh_kind)
                if not args.quiet:
                    if row["ok"]:
                        m = row["memory"]
                        print(f"[OK]   {arch:28s} {shape:12s} {mesh_kind:6s} "
                              f"lower={row['lower_s']:6.1f}s "
                              f"compile={row['compile_s']:6.1f}s "
                              f"peak/dev={m['peak_estimate_bytes']/2**30:6.2f}GiB "
                              f"flops/dev={row['flops_per_device']:.3e} "
                              f"coll={row['collectives']['moved_bytes']:.3e}B",
                              flush=True)
                    else:
                        failures += 1
                        print(f"[FAIL] {arch:28s} {shape:12s} {mesh_kind:6s} "
                              f"{row['error'][:160]}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
