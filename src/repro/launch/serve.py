"""Serving launcher: edge-draft + cloud-target speculative decoding on real
JAX models with the paper's window policies, on the continuous slot-based
scheduler (default) or the wave-batched baseline.

TOPOLOGY-FIRST: the launcher's real input is a declarative
:class:`repro.topology.ClusterSpec` — nodes, draft→target pairs with
per-pair links/window/mode policies, serving knobs, workload:

    PYTHONPATH=src python -m repro.launch.serve \
        --topology examples/cluster_2pair.json [--requests 8] [--json]

The legacy flag surface still works and compiles down to an equivalent
ONE-PAIR spec through :func:`repro.topology.one_pair_spec` and the same
:func:`repro.topology.build_deployment` factory (old invocations stay
behaviorally identical):

    PYTHONPATH=src python -m repro.launch.serve \
        --target qwen3-14b --draft qwen2.5-3b --policy awc \
        --requests 16 --max-new 48 [--server continuous|wave] \
        [--arrival-rate 8] [--temperature 0.0] [--rtt-ms 10] \
        [--link-rtt-ms 20 --link-jitter-ms 2 --link-bw-gbps 1] \
        [--mode-policy auto|distributed|fused|pipeline]

``--arrival-rate`` draws Poisson arrivals (requests/s); TTFT and e2e are
measured from each request's arrival, so they include queue wait. Reduced-
variant models by default (this is the host-runnable driver; the full
configs exercise the dry-run path).

``--link-rtt-ms`` switches the continuous server to DISTRIBUTED execution:
speculation rounds run as real draft→verify→verdict exchanges over a
transport — zero-delay in-process at ``--link-rtt-ms 0`` (bit-identical to
the colocated path), an emulated edge-cloud link otherwise (measured
wall-clock delays; ``--link-jitter-ms``/``--link-bw-gbps`` shape it, and
the measured RTT feeds the AWC feature vector). ``--mode-policy`` forces
or frees the fused/distributed mode decision (``fused`` = cloud-only
autoregressive steps, no draft round trips).

Multi-pair topologies report link stats PER PAIR (``pairs`` in the JSON
summary, keyed by pair id); the one-pair case additionally keeps the old
flat ``link_*`` keys.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from ..configs import ARCHS
from ..serving import ServeRequest, WaveSpecDecodeServer
from ..topology import ClusterSpec, build_deployment, one_pair_spec


def spec_from_args(args) -> ClusterSpec:
    """Compile the parsed CLI namespace to a ClusterSpec: ``--topology``
    loads the file (CLI workload flags override its workload section when
    explicitly passed); otherwise the legacy flags map to a one-pair
    spec."""
    if args.topology:
        spec = ClusterSpec.load(args.topology)
    else:
        spec = one_pair_spec(
            target=args.target, draft=args.draft, policy=args.policy,
            gamma=args.gamma, gamma_max=args.gamma_max,
            max_batch=args.max_batch, sync_every=args.sync_every,
            temperature=args.temperature, rtt_ms=args.rtt_ms,
            link_rtt_ms=args.link_rtt_ms,
            link_jitter_ms=args.link_jitter_ms,
            link_bw_gbps=args.link_bw_gbps, mode_policy=args.mode_policy,
            server=args.server, seed=args.seed)
    if args.requests is not None:
        spec.workload.num_requests = args.requests
    if args.max_new is not None:
        spec.workload.max_new = args.max_new
    if args.arrival_rate is not None:
        spec.workload.rate_per_s = args.arrival_rate
    return spec.validate()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default=None, metavar="cluster.json",
                    help="declarative ClusterSpec (nodes + draft→target "
                         "pairs with per-pair links/policies); replaces "
                         "the one-pair flag surface below")
    ap.add_argument("--target", default="qwen3-14b", choices=sorted(ARCHS))
    ap.add_argument("--draft", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--policy", default="static",
                    choices=["static", "dynamic", "awc"])
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default: topology workload, or 8)")
    ap.add_argument("--max-new", type=int, default=None,
                    help="tokens per request (default: topology workload, "
                         "or 32)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--server", default="continuous",
                    choices=["continuous", "wave"],
                    help="continuous slot scheduler vs wave-batched baseline")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals per second (0 = all at t=0)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rtt-ms", type=float, default=10.0,
                    help="virtual RTT charged by the colocated path "
                         "(ignored when --link-rtt-ms selects a transport)")
    ap.add_argument("--link-rtt-ms", type=float, default=None,
                    help="run distributed over a transport: 0 = in-process "
                         "(zero delay), >0 = emulated edge-cloud link with "
                         "this RTT (measured wall-clock delays)")
    ap.add_argument("--link-jitter-ms", type=float, default=1.0,
                    help="emulated link jitter (with --link-rtt-ms > 0)")
    ap.add_argument("--link-bw-gbps", type=float, default=1.0,
                    help="emulated link bandwidth (with --link-rtt-ms > 0)")
    ap.add_argument("--mode-policy", default="auto",
                    choices=["auto", "distributed", "fused", "pipeline"],
                    help="honor the window policy's fused/distributed "
                         "decision (auto) or force one mode; 'pipeline' "
                         "honors the decision AND overlaps window k+1's "
                         "draft with window k's verification (needs "
                         "--link-rtt-ms; pays off when RTT is at least "
                         "the target step time)")
    ap.add_argument("--gamma-max", type=int, default=12,
                    help="compile-once window bound; any policy γ ≤ this "
                         "runs without recompiling")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode iterations between host stat syncs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    if args.link_rtt_ms is not None and args.server == "wave":
        raise SystemExit("--link-rtt-ms needs the continuous server "
                         "(the wave baseline is colocated-only)")
    if args.mode_policy == "pipeline" and args.link_rtt_ms is None \
            and not args.topology:
        raise SystemExit("--mode-policy pipeline overlaps rounds across a "
                         "transport; pass --link-rtt-ms (0 = in-process)")

    spec = spec_from_args(args)
    deployment = build_deployment(spec)
    wl = spec.workload

    if spec.serving.server == "wave":
        pair0 = deployment.pairs[0]
        cfg = deployment.server_config()
        # the wave baseline reads mode_policy off its ServerConfig (it has
        # no pair objects); forward the single pair's declared mode
        cfg.mode_policy = pair0.mode_policy
        server = WaveSpecDecodeServer(pair0.engine, pair0.policy, cfg)
    else:
        server = deployment.build_server()

    fleet_reqs = None
    if wl.trace is not None:
        # fleet trace: class-aware arrivals with per-class SLOs attached
        # to every request — the SAME stream build_simulation replays
        from ..fleet.workload import fleet_serve_requests, generate_requests
        fleet_reqs = generate_requests(wl.trace)
        for req in fleet_serve_requests(fleet_reqs, deployment.vocab,
                                        seed=spec.seed):
            server.submit(req)
    else:
        rng = np.random.default_rng(spec.seed)
        arrival = 0.0
        for i in range(wl.num_requests):
            plen = int(rng.integers(wl.prompt_lo, wl.prompt_hi))
            if wl.rate_per_s > 0:
                arrival += float(rng.exponential(1.0 / wl.rate_per_s))
            server.submit(ServeRequest(
                i, rng.integers(0, deployment.vocab, plen).astype(np.int32),
                wl.max_new, arrival_s=arrival))
    try:
        results = server.run()
    finally:
        # process-backed pairs hold worker subprocesses; their cached wave
        # stats survive shutdown, so summaries below still read correctly
        deployment.shutdown()

    accs = [r.acceptance_rate for r in results]
    tpots = [r.tpot_ms for r in results]
    summary = {
        "server": spec.serving.server,
        "topology": args.topology or "one-pair(flags)",
        "pairs_deployed": len(deployment.pairs),
        "requests": len(results),
        "mean_acceptance": float(np.mean(accs)),
        "mean_ttft_ms": float(np.mean([r.ttft_ms for r in results])),
        "mean_queue_ms": float(np.mean([r.queue_ms for r in results])),
        "mean_tpot_ms": float(np.mean(tpots)),
        "mean_e2e_ms": float(np.mean([r.e2e_ms for r in results])),
        "compiled_step_programs": sum(
            p.engine.compiled_programs()
            for p in {id(p.engine): p for p in deployment.pairs
                      if p.engine is not None}.values()),
    }
    if not args.topology:
        summary["policy"] = args.policy
    if fleet_reqs is not None:
        from ..fleet.workload import serve_results_rows, slo_report
        summary["slo"] = slo_report(serve_results_rows(results))
    if hasattr(server, "pair_summaries"):
        summary["pairs"] = server.pair_summaries()
    # one-pair backcompat: the flat link keys the pre-topology launcher
    # emitted, read off the single pair's transport
    if len(deployment.pairs) == 1:
        tr = deployment.pairs[0].transport
        if tr is not None:
            summary["transport"] = tr.describe()
            summary["mode_policy"] = deployment.pairs[0].mode_policy
            summary["link_bytes_sent"] = tr.bytes_sent
            summary["link_messages"] = tr.messages_sent
            summary["link_recent_rtt_ms"] = round(tr.recent_rtt_ms, 3)
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        per_pair = ""
        if len(deployment.pairs) > 1 and "pairs" in summary:
            per_pair = "  " + "  ".join(
                (f"[{pid}: γ={d['mean_gamma']:.2f} "
                 f"fused={d['fused_fraction']:.2f} n={d['requests']}]")
                if "mean_gamma" in d else
                (f"[{pid}: process acc={d.get('acceptance_rate', 0):.2f} "
                 f"n={d['requests']}]")
                for pid, d in summary["pairs"].items())
        slo_txt = ""
        if "slo" in summary and summary["slo"]["graded"]:
            slo_txt = f"  slo={summary['slo']['attainment']:.2f}"
        print(f"served {summary['requests']} requests  "
              f"server={summary['server']}  "
              f"pairs={summary['pairs_deployed']}  "
              f"acceptance={summary['mean_acceptance']:.3f}  "
              f"ttft={summary['mean_ttft_ms']:.1f}ms  "
              f"tpot={summary['mean_tpot_ms']:.1f}ms  "
              f"e2e={summary['mean_e2e_ms']:.0f}ms  "
              f"programs={summary['compiled_step_programs']}"
              + slo_txt + per_pair)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
