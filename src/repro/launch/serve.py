"""Serving launcher: edge-draft + cloud-target speculative decoding on real
JAX models with the paper's window policies, on the continuous slot-based
scheduler (default) or the wave-batched baseline.

    PYTHONPATH=src python -m repro.launch.serve \
        --target qwen3-14b --draft qwen2.5-3b --policy awc \
        --requests 16 --max-new 48 [--server continuous|wave] \
        [--arrival-rate 8] [--temperature 0.0] [--rtt-ms 10] \
        [--link-rtt-ms 20 --link-jitter-ms 2 --link-bw-gbps 1] \
        [--mode-policy auto|distributed|fused]

``--arrival-rate`` draws Poisson arrivals (requests/s); TTFT and e2e are
measured from each request's arrival, so they include queue wait. Reduced-
variant models by default (this is the host-runnable driver; the full
configs exercise the dry-run path).

``--link-rtt-ms`` switches the continuous server to DISTRIBUTED execution:
speculation rounds run as real draft→verify→verdict exchanges over a
transport — zero-delay in-process at ``--link-rtt-ms 0`` (bit-identical to
the colocated path), an emulated edge-cloud link otherwise (measured
wall-clock delays; ``--link-jitter-ms``/``--link-bw-gbps`` shape it, and
the measured RTT feeds the AWC feature vector). ``--mode-policy`` forces
or frees the fused/distributed mode decision (``fused`` = cloud-only
autoregressive steps, no draft round trips).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from ..configs import ARCHS, get_config
from ..core.engine import SpecDecodeEngine
from ..core.window import (AWCWindowPolicy, DynamicWindowPolicy,
                           StaticWindowPolicy)
from ..core.awc.model import default_predictor
from ..serving import (ServeRequest, ServerConfig, SpecDecodeServer,
                       WaveSpecDecodeServer)


def build_policy(name: str, gamma: int):
    if name == "static":
        return StaticWindowPolicy(gamma)
    if name == "dynamic":
        return DynamicWindowPolicy(gamma0=gamma)
    if name == "awc":
        return AWCWindowPolicy(default_predictor())
    raise ValueError(name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="qwen3-14b", choices=sorted(ARCHS))
    ap.add_argument("--draft", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--policy", default="static",
                    choices=["static", "dynamic", "awc"])
    ap.add_argument("--gamma", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--server", default="continuous",
                    choices=["continuous", "wave"],
                    help="continuous slot scheduler vs wave-batched baseline")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals per second (0 = all at t=0)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rtt-ms", type=float, default=10.0,
                    help="virtual RTT charged by the colocated path "
                         "(ignored when --link-rtt-ms selects a transport)")
    ap.add_argument("--link-rtt-ms", type=float, default=None,
                    help="run distributed over a transport: 0 = in-process "
                         "(zero delay), >0 = emulated edge-cloud link with "
                         "this RTT (measured wall-clock delays)")
    ap.add_argument("--link-jitter-ms", type=float, default=1.0,
                    help="emulated link jitter (with --link-rtt-ms > 0)")
    ap.add_argument("--link-bw-gbps", type=float, default=1.0,
                    help="emulated link bandwidth (with --link-rtt-ms > 0)")
    ap.add_argument("--mode-policy", default="auto",
                    choices=["auto", "distributed", "fused", "pipeline"],
                    help="honor the window policy's fused/distributed "
                         "decision (auto) or force one mode; 'pipeline' "
                         "honors the decision AND overlaps window k+1's "
                         "draft with window k's verification (needs "
                         "--link-rtt-ms; pays off when RTT is at least "
                         "the target step time)")
    ap.add_argument("--gamma-max", type=int, default=12,
                    help="compile-once window bound; any policy γ ≤ this "
                         "runs without recompiling")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode iterations between host stat syncs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    if args.link_rtt_ms is not None and args.server == "wave":
        raise SystemExit("--link-rtt-ms needs the continuous server "
                         "(the wave baseline is colocated-only)")
    if args.mode_policy == "pipeline" and args.link_rtt_ms is None:
        raise SystemExit("--mode-policy pipeline overlaps rounds across a "
                         "transport; pass --link-rtt-ms (0 = in-process)")

    tcfg = get_config(args.target).reduced()
    dcfg = get_config(args.draft).reduced()
    # draft and target must share a vocab (one tokenizer)
    vocab = min(tcfg.vocab, dcfg.vocab)
    tcfg = dataclasses.replace(tcfg, vocab=vocab)
    dcfg = dataclasses.replace(dcfg, vocab=vocab)

    engine = SpecDecodeEngine(dcfg, tcfg, temperature=args.temperature,
                              rtt_ms=args.rtt_ms,
                              gamma_max=args.gamma_max,
                              sync_every=args.sync_every,
                              key=jax.random.PRNGKey(args.seed))
    transport = None
    if args.link_rtt_ms is not None:
        from ..distributed import EmulatedLinkTransport, InProcessTransport
        from ..sim.network import LinkSpec
        if args.link_rtt_ms <= 0:
            transport = InProcessTransport()
        else:
            transport = EmulatedLinkTransport(
                LinkSpec(rtt_ms=args.link_rtt_ms,
                         jitter_ms=args.link_jitter_ms,
                         bandwidth_gbps=args.link_bw_gbps),
                seed=args.seed)
    server_cls = (SpecDecodeServer if args.server == "continuous"
                  else WaveSpecDecodeServer)
    server = server_cls(engine, build_policy(args.policy, args.gamma),
                        ServerConfig(max_batch=args.max_batch,
                                     transport=transport,
                                     mode_policy=args.mode_policy))
    rng = np.random.default_rng(args.seed)
    arrival = 0.0
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        if args.arrival_rate > 0:
            arrival += float(rng.exponential(1.0 / args.arrival_rate))
        server.submit(ServeRequest(
            i, rng.integers(0, vocab, plen).astype(np.int32), args.max_new,
            arrival_s=arrival))
    results = server.run()

    accs = [r.acceptance_rate for r in results]
    tpots = [r.tpot_ms for r in results]
    summary = {
        "server": args.server,
        "policy": args.policy,
        "requests": len(results),
        "mean_acceptance": float(np.mean(accs)),
        "mean_ttft_ms": float(np.mean([r.ttft_ms for r in results])),
        "mean_queue_ms": float(np.mean([r.queue_ms for r in results])),
        "mean_tpot_ms": float(np.mean(tpots)),
        "mean_e2e_ms": float(np.mean([r.e2e_ms for r in results])),
        "compiled_step_programs": engine.compiled_programs(),
    }
    if transport is not None:
        summary["transport"] = transport.describe()
        summary["mode_policy"] = args.mode_policy
        summary["link_bytes_sent"] = transport.bytes_sent
        summary["link_messages"] = transport.messages_sent
        summary["link_recent_rtt_ms"] = round(transport.recent_rtt_ms, 3)
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(f"served {summary['requests']} requests  "
              f"server={args.server}  policy={args.policy}  "
              f"acceptance={summary['mean_acceptance']:.3f}  "
              f"ttft={summary['mean_ttft_ms']:.1f}ms  "
              f"tpot={summary['mean_tpot_ms']:.1f}ms  "
              f"e2e={summary['mean_e2e_ms']:.0f}ms  "
              f"programs={summary['compiled_step_programs']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
