"""Grouped-query attention with the variants the assigned archs need:
qk_norm (qwen3), qkv bias (qwen2.5), sliding window (long-context serving),
cross-attention (whisper decoder), ring-buffer KV caches, and pos_map-masked
decode (speculative rollback; see models/kvcache.py).

Functions are per-layer and pure; model.py stacks their params and scans.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, causal_mask, dense_init, rms_norm
from .kvcache import (gather_layer_paged, paged_update_layer,
                      update_layer_cache)
from ..sharding.runtime import constrain_qkv


def init_attn_params(key: jax.Array, cfg: ModelConfig, dtype,
                     cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, kv, hd), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, kv, hd), dtype, fan_in=d),
        "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_q(x, p, cfg):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return constrain_qkv(q)


def _project_kv(x, p, cfg):
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return constrain_qkv(k), constrain_qkv(v)


def _repeat_kv(kv, H):
    """(B,S,Hkv,hd) → (B,S,H,hd). Repeating KV to full heads keeps attention
    a clean 4-D einsum that GSPMD shards exactly on the head dim (H is a
    multiple of the model axis for most archs) — the 5-D (Hkv,G)-split
    formulation forced involuntary replication of O(S²) score tensors in
    the backward pass (§Perf cycle 4). The GQA bandwidth saving is a
    property of the serving kernel (kernels/decode_attn), not of the
    training einsum — same trade Megatron/MaxText make."""
    Hkv = kv.shape[2]
    if Hkv == H:
        return kv
    return jnp.repeat(kv, H // Hkv, axis=2)


def _gqa_scores(q, k):
    """q: (B,T,H,hd), k: (B,S,Hkv,hd) → (B,H,T,S)."""
    k = _repeat_kv(k, q.shape[2])
    return jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(q.shape[-1])


def _gqa_out(weights, v, p):
    """weights: (B,H,T,S), v: (B,S,Hkv,hd) → (B,T,D)."""
    v = _repeat_kv(v, weights.shape[1])
    ctx = jnp.einsum("bhts,bshd->bthd", weights, v)
    return jnp.einsum("bthk,hkd->btd", ctx, p["wo"])


def attention_train(x: jax.Array, p: dict, cfg: ModelConfig,
                    positions: Optional[jax.Array] = None,
                    window: Optional[int] = None,
                    prefix_len: int = 0, q_chunk: int = 1024) -> jax.Array:
    """Full-sequence causal self-attention (training / prefill compute path).

    ``prefix_len`` marks a bidirectional prefix (VLM image tokens attend
    freely within the prefix; text remains causal) — 0 for plain LMs.

    Long sequences (> q_chunk) process queries in chunks via ``lax.scan`` so
    the (T, T) score matrix never materializes — the flash-attention memory
    shape, required for the 32k prefill/train shapes (a 32k² f32 score
    tensor would be ~4 GB per head). Chunks attend to the full (masked) K,
    trading ≤2× causal-triangle flops for O(C·T) memory.
    """
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :].astype(jnp.int32)
    q = apply_rope(_project_q(x, p, cfg), positions, cfg.rope_theta)
    k, v = _project_kv(x, p, cfg)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = window if window is not None else cfg.sliding_window

    def masked_attend(q_blk, offset):
        """q_blk: (B, C, H, hd); offset: absolute pos of q_blk[…,0]."""
        C = q_blk.shape[1]
        mask = causal_mask(C, T, offset, w)
        if prefix_len > 0:
            pre = ((jnp.arange(C)[:, None] + offset) < prefix_len) & \
                (jnp.arange(T)[None, :] < prefix_len)
            mask = mask | pre
        scores = _gqa_scores(q_blk, k)
        scores = jnp.where(mask[None, None],
                           scores.astype(jnp.float32), -jnp.inf)
        weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        return _gqa_out(weights, v, p)

    if T <= q_chunk or T % q_chunk != 0:
        return masked_attend(q, 0)

    n_chunks = T // q_chunk
    q_blocks = q.reshape(B, n_chunks, q_chunk, *q.shape[2:]).swapaxes(0, 1)
    offsets = jnp.arange(n_chunks) * q_chunk

    def step(_, inp):
        qb, off = inp
        return None, masked_attend(qb, off)

    _, out = jax.lax.scan(step, None, (q_blocks, offsets))
    # masked_attend output is already projected: (n_chunks, B, C, d_model)
    return out.swapaxes(0, 1).reshape(B, T, x.shape[-1])


def attention_bidir(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Bidirectional self-attention (whisper encoder)."""
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :].astype(jnp.int32)
    q = apply_rope(_project_q(x, p, cfg), positions, cfg.rope_theta)
    k, v = _project_kv(x, p, cfg)
    k = apply_rope(k, positions, cfg.rope_theta)
    scores = _gqa_scores(q, k).astype(jnp.float32)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return _gqa_out(weights, v, p)


def attention_cross(x: jax.Array, p: dict, cfg: ModelConfig,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Cross-attention over precomputed encoder K/V (whisper decoder)."""
    q = _project_q(x, p, cfg)   # no rope on cross-attn queries
    scores = _gqa_scores(q, enc_k).astype(jnp.float32)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    return _gqa_out(weights, v=enc_v, p=p)


def cross_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute encoder K/V once per request (the whisper 'prefill')."""
    return _project_kv(enc_out, p, cfg)


def attention_decode(x_new: jax.Array, p: dict, cfg: ModelConfig,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos_map: jax.Array, pos: jax.Array, ring: bool,
                     window: int = 0, uniform_pos: bool = False,
                     slot_off: Optional[jax.Array] = None,
                     pos_off: Optional[jax.Array] = None,
                     win_mask: Optional[jax.Array] = None):
    """Decode/verify step: write the (B,T) window into the cache, attend over
    valid slots.

    x_new: (B, T, D); pos: (B,) absolute position of x_new[:, 0].
    Validity mask per slot s for query t:  0 ≤ pos_map[s] ≤ pos+t, and
    pos_map[s] > pos+t − window when sliding. Stale speculative entries
    (pos_map beyond the committed position) are excluded automatically.

    Tree speculation (``slot_off``/``pos_off``/``win_mask``): token t
    writes slot ``pos + slot_off[t]`` at logical position
    ``pos + pos_off[t]`` (RoPE phase and pos_map value), and for cache
    slots inside the window region ``[pos, pos + win_mask.shape[1])`` the
    validity of slot ``pos + j`` for query t is OVERRIDDEN by
    ``win_mask[t, j]`` — sibling branches tie on position, so the base
    ``slot_pos ≤ q_pos`` rule cannot separate them; the ancestor bitmap
    does. Slots outside the region keep the base rule (the committed
    prefix stays visible).
    Returns (out, k_cache, v_cache, pos_map).
    """
    B, T, _ = x_new.shape
    off = jnp.arange(T) if pos_off is None else pos_off
    abs_pos = pos[:, None] + off[None, :]                      # (B, T)
    q = apply_rope(_project_q(x_new, p, cfg), abs_pos, cfg.rope_theta)
    k_new, v_new = _project_kv(x_new, p, cfg)
    k_new = apply_rope(k_new, abs_pos, cfg.rope_theta)
    k_cache, v_cache, pos_map = update_layer_cache(
        k_cache, v_cache, pos_map, k_new, v_new, pos, ring,
        uniform_pos=uniform_pos, slot_off=slot_off, pos_off=pos_off)

    out = _attend_cached(q, k_cache, v_cache, pos_map, abs_pos, window,
                         p["wo"], x_new.dtype, win_mask=win_mask, pos=pos)
    return out, k_cache, v_cache, pos_map


def _attend_cached(q, k_cache, v_cache, pos_map, abs_pos, window, wo,
                   out_dtype, win_mask=None, pos=None):
    """Attend rope'd queries (B,T,H,hd) over a position-ordered cache view
    (B,S,Hkv,hd) + pos_map (B,S). Shared by the dense and paged decode
    paths — the paged path gathers its pool into exactly this view, so both
    run the identical einsum/mask/softmax program (bit-identical on equal
    values).

    decode is memory-bound and has no backward: use the GROUPED einsum so
    the KV cache is read once per kv-head, not G x via repeat (the 4-D
    repeat form serves the training path's GSPMD-friendly backward; the
    TPU serving kernel kernels/decode_attn implements the same grouping)."""
    B_, T_, H_, hd_ = q.shape
    Hkv_ = k_cache.shape[2]
    G_ = H_ // Hkv_
    qg = q.reshape(B_, T_, Hkv_, G_, hd_)
    # f32 accumulation via preferred_element_type: a separate .astype(f32)
    # made XLA materialize an f32 copy of the whole cache shard per layer
    # (§Perf decode cycle: 4 GiB x L buffers)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k_cache,
                        preferred_element_type=jnp.float32) / math.sqrt(hd_)
    slot_pos = pos_map[:, None, None, None, :]                  # (B,1,1,1,S)
    q_pos = abs_pos[:, None, None, :, None]                     # (B,1,1,T,1)
    valid = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window > 0:
        valid = valid & (slot_pos > q_pos - window)
    if win_mask is not None:
        # Tree window override: slot pos+j obeys win_mask[t, j] instead of
        # the position rule, for j in [0, Wn) (see attention_decode).
        Wn = win_mask.shape[1]
        S_ = pos_map.shape[1]
        rel = jnp.arange(S_)[None, :] - pos[:, None]            # (B, S)
        in_region = (rel >= 0) & (rel < Wn)
        ov = jnp.take(win_mask, jnp.clip(rel, 0, Wn - 1), axis=1)  # (T,B,S)
        ov = jnp.moveaxis(ov, 0, 1)[:, None, None, :, :]        # (B,1,1,T,S)
        valid = jnp.where(in_region[:, None, None, None, :],
                          ov, valid)
    scores = jnp.where(valid, scores, -jnp.inf)
    weights = jax.nn.softmax(scores, axis=-1).astype(out_dtype)
    ctx = jnp.einsum("bkgts,bskh->btkgh", weights, v_cache)
    ctx = ctx.reshape(B_, T_, H_, hd_)
    return jnp.einsum("bthk,hkd->btd", ctx, wo)


def attention_decode_paged(x_new: jax.Array, p: dict, cfg: ModelConfig,
                           k_pool: jax.Array, v_pool: jax.Array,
                           k_scale, v_scale, pos_map: jax.Array,
                           block_table: jax.Array, pos: jax.Array,
                           ring: bool, length: int, window: int = 0,
                           use_kernel: Optional[bool] = None):
    """Paged decode/verify step: write the (B,T) window into the block pool
    through the slot block tables, then attend over the slot's mapped
    blocks. Single-layer pool views: k/v (NB, bs, Hkv, hd), pos_map
    (NB, bs); block_table (B, n_log) is shared across layers and NOT
    updated here.

    Identical masking semantics to :func:`attention_decode` — the fp pool
    is bit-identical to a dense cache of size ``length`` (int8 pools are
    approximate by construction). ``use_kernel=None`` auto-selects the
    fused Pallas paged kernel on TPU backends and the XLA gather path
    elsewhere. Returns (out, k_pool, v_pool, k_scale, v_scale, pos_map).
    """
    B, T, _ = x_new.shape
    abs_pos = pos[:, None] + jnp.arange(T)[None, :]            # (B, T)
    q = apply_rope(_project_q(x_new, p, cfg), abs_pos, cfg.rope_theta)
    k_new, v_new = _project_kv(x_new, p, cfg)
    k_new = apply_rope(k_new, abs_pos, cfg.rope_theta)
    k_pool, v_pool, k_scale, v_scale, pos_map = paged_update_layer(
        k_pool, v_pool, k_scale, v_scale, pos_map, block_table,
        k_new, v_new, pos, ring, length)

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        # fused path: the kernel grid walks each slot's block list via
        # scalar-prefetch indirection — no dense gather materializes
        from ..kernels.decode_attn.paged import paged_decode_attention
        B_, T_, H_, hd_ = q.shape
        Hkv_ = k_pool.shape[2]
        qg = q.reshape(B_, T_, Hkv_, H_ // Hkv_, hd_)
        ctx = paged_decode_attention(qg, k_pool, v_pool, k_scale, v_scale,
                                     pos_map, block_table, abs_pos,
                                     length=length, window=window)
        ctx = ctx.astype(x_new.dtype).reshape(B_, T_, H_, hd_)
        out = jnp.einsum("bthk,hkd->btd", ctx, p["wo"])
    else:
        k_d, v_d, pm_d = gather_layer_paged(
            k_pool, v_pool, k_scale, v_scale, pos_map, block_table,
            length, x_new.dtype)
        out = _attend_cached(q, k_d, v_d, pm_d, abs_pos, window, p["wo"],
                             x_new.dtype)
    return out, k_pool, v_pool, k_scale, v_scale, pos_map
