"""Mixture-of-Experts FFN — GShard-style capacity-based einsum dispatch.

TPU-native formulation: tokens are grouped per batch row, each expert has
capacity ``c = ceil(S/E · cf · k)``, and dispatch/combine are dense one-hot
einsums that GSPMD shards cleanly (groups → ``data`` axis, experts →
``model`` axis ⇒ the dispatch einsum lowers to an all-to-all on ``model``).
Supports top-1 (llama4-maverick) and top-2 (arctic) routing plus arctic's
parallel dense-residual MLP. Overflowing tokens are dropped (contribute zero
from the MoE branch) per the standard capacity formulation; the router
aux-loss pushes load balance so drops stay rare.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, swiglu


def init_moe_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "w_gate": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "w_up": dense_init(ks[2], (e, d, f), dtype, fan_in=d),
        "w_down": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }
    if cfg.moe_dense_residual:
        p["res_gate"] = dense_init(ks[4], (d, f), dtype, fan_in=d)
        p["res_up"] = dense_init(ks[5], (d, f), dtype, fan_in=d)
        p["res_down"] = dense_init(ks[6], (f, d), dtype, fan_in=f)
    return p


def capacity(seq: int, n_experts: int, k: int, cf: float) -> int:
    return max(1, int(math.ceil(seq / n_experts * cf * k)))


def moe_block(x: jax.Array, p: dict, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y, aux_loss). Dense-dispatch MoE with capacity.

    GShard grouping: sequences longer than ``cfg.moe_group`` are split into
    groups of that many tokens, each with its own capacity — otherwise the
    (tokens, E, c) dispatch tensor grows quadratically with S (a 32k
    sequence would need a ~TB dispatch tensor; grouped it is O(S·E·c/g)).
    The (B·groups) leading dim keeps the batch ('data') sharding.
    """
    B0, S0, D = x.shape
    g = getattr(cfg, "moe_group", 4096) or 4096
    grouped = S0 > g and S0 % g == 0
    if grouped:
        x = x.reshape(B0 * (S0 // g), g, D)
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    c = capacity(S, E, k, cfg.capacity_factor)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                     # (B,S,E)

    # top-k selection, sequential capacity accounting across ranks
    topk_gate, topk_idx = jax.lax.top_k(gates, k)               # (B,S,k)
    # normalize selected gates to sum to 1 (standard top-2 renorm)
    topk_gate = topk_gate / jnp.maximum(
        topk_gate.sum(-1, keepdims=True), 1e-9)

    counts = jnp.zeros((B, E), jnp.int32)
    dispatch = jnp.zeros((B, S, E, c), x.dtype)
    combine = jnp.zeros((B, S, E, c), jnp.float32)
    for r in range(k):
        onehot = jax.nn.one_hot(topk_idx[..., r], E, dtype=jnp.int32)  # (B,S,E)
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        keep = (pos < c) & (onehot > 0)
        slot = jax.nn.one_hot(jnp.clip(pos, 0, c - 1), c, dtype=x.dtype)
        disp_r = keep[..., None].astype(x.dtype) * onehot[..., None].astype(x.dtype) * slot
        dispatch = dispatch + disp_r
        combine = combine + disp_r.astype(jnp.float32) * topk_gate[..., r][..., None, None]
        counts = counts + jnp.sum(onehot, axis=1)

    # expert compute: (B,S,E,c) x (B,S,D) -> (E,B,c,D)
    xe = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    g = jnp.einsum("ebcd,edf->ebcf", xe, p["w_gate"])
    u = jnp.einsum("ebcd,edf->ebcf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    yo = jnp.einsum("ebcf,efd->ebcd", h, p["w_down"])
    y = jnp.einsum("ebcd,bsec->bsd", yo, combine.astype(x.dtype))

    if cfg.moe_dense_residual:
        y = y + swiglu(x, p["res_gate"], p["res_up"], p["res_down"])

    # GShard aux load-balance loss: E * Σ_e f_e · P_e
    me = jnp.mean(gates, axis=(0, 1))                            # (E,)
    fe = jnp.mean(
        jax.nn.one_hot(topk_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    if grouped:
        y = y.reshape(B0, S0, D)
    return y, aux
