"""Unified model zoo assembler.

One :class:`Model` class covers all six families via ``cfg.arch_type``:

- ``dense`` / ``vlm``  — GQA transformer LM (vlm consumes stub patch embeds
  as a bidirectional prefix),
- ``moe``              — GQA attention + GShard capacity-dispatch MoE FFN,
- ``ssm``              — Mamba2/SSD stack (attention-free),
- ``hybrid``           — Zamba2-style Mamba2 backbone + one *shared*
  attention block invoked every ``attn_every`` layers,
- ``encdec``           — whisper-style audio encoder (stub conv frontend
  embeddings) + text decoder with cross-attention.

API (uniform across families, everything jit/pjit-able):

    params = model.init_params(key)
    logits, aux = model.forward_train(params, batch)
    logits, cache = model.prefill(params, tokens, frontend=..., slots=N)
    logits, cache = model.decode_step(params, token, cache, pos)   # T = 1
    logits, cache = model.verify_step(params, window, cache, pos)  # T = γ+1

Layers are stacked and scanned (``lax.scan``) so HLO size and compile time
stay flat in depth — required for the 80-layer archs in the dry-run.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import dense_init, dtype_of, rms_norm, swiglu
from .attention import (attention_bidir, attention_cross, attention_decode,
                        attention_decode_paged, attention_train, cross_kv,
                        init_attn_params)
from .moe import init_moe_params, moe_block
from .ssm import SSDState, init_ssm_params, ssm_block_decode, ssm_block_train
from .kvcache import (AttnCache, PagedAttnCache, SSMCache, init_attn_cache,
                      init_paged_attn_cache, init_ssm_cache)
from ..sharding.runtime import (constrain, constrain_head_in,
                                constrain_logits)


class EncDecCache(NamedTuple):
    self_attn: AttnCache
    cross_k: jax.Array     # (L, B, F, Hkv, hd)
    cross_v: jax.Array


class HybridCacheT(NamedTuple):
    ssm: SSMCache
    shared_attn: AttnCache   # L axis = number of shared-block invocations


def _stack_init(key: jax.Array, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = dtype_of(cfg.dtype)

    # ------------------------------------------------------------------ init

    def _init_block(self, key: jax.Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 3)
        if cfg.arch_type in ("dense", "vlm", "moe", "encdec"):
            p = {"ln1": jnp.zeros((cfg.d_model,), dt),
                 "ln2": jnp.zeros((cfg.d_model,), dt),
                 "attn": init_attn_params(ks[0], cfg, dt)}
            if cfg.arch_type == "moe":
                p["moe"] = init_moe_params(ks[1], cfg, dt)
            else:
                f = cfg.d_ff
                k1, k2, k3 = jax.random.split(ks[1], 3)
                p["mlp"] = {
                    "w_gate": dense_init(k1, (cfg.d_model, f), dt),
                    "w_up": dense_init(k2, (cfg.d_model, f), dt),
                    "w_down": dense_init(k3, (f, cfg.d_model), dt, fan_in=f)}
            if cfg.arch_type == "encdec":     # decoder gets cross-attention
                p["ln_x"] = jnp.zeros((cfg.d_model,), dt)
                p["xattn"] = init_attn_params(ks[2], cfg, dt, cross=True)
            return p
        if cfg.arch_type in ("ssm", "hybrid"):
            return {"ln1": jnp.zeros((cfg.d_model,), dt),
                    "ssm": init_ssm_params(ks[0], cfg, dt)}
        raise ValueError(cfg.arch_type)

    def init_params(self, key: jax.Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), dt,
                                fan_in=cfg.d_model),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
            "layers": _stack_init(keys[1], cfg.n_layers, self._init_block),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab),
                                           dt)
        if cfg.arch_type == "hybrid":
            k1, k2 = jax.random.split(keys[3])
            f = cfg.d_ff
            ka, kb, kc = jax.random.split(k2, 3)
            params["shared_attn"] = {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attn_params(k1, cfg, dt),
                "mlp": {"w_gate": dense_init(ka, (cfg.d_model, f), dt),
                        "w_up": dense_init(kb, (cfg.d_model, f), dt),
                        "w_down": dense_init(kc, (f, cfg.d_model), dt,
                                             fan_in=f)}}
        if cfg.arch_type == "encdec":
            params["encoder"] = _stack_init(
                keys[4], cfg.encoder_layers,
                lambda k: self._enc_block(k))
            params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
        return params

    def _enc_block(self, key: jax.Array) -> dict:
        cfg, dt = self.cfg, self.dtype
        k0, k1 = jax.random.split(key)
        ka, kb, kc = jax.random.split(k1, 3)
        f = cfg.d_ff
        return {"ln1": jnp.zeros((cfg.d_model,), dt),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "attn": init_attn_params(k0, cfg, dt),
                "mlp": {"w_gate": dense_init(ka, (cfg.d_model, f), dt),
                        "w_up": dense_init(kb, (cfg.d_model, f), dt),
                        "w_down": dense_init(kc, (f, cfg.d_model), dt,
                                             fan_in=f)}}

    # ------------------------------------------------------------ primitives

    def _logits(self, params, h):
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = constrain_head_in(h)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        out = jnp.einsum("...d,dv->...v", h, head).astype(jnp.float32)
        return constrain_logits(out)

    def _mlp_or_moe(self, lp: dict, h: jax.Array):
        cfg = self.cfg
        hn = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            y, aux = moe_block(hn, lp["moe"], cfg)
            return h + y, aux
        return h + swiglu(hn, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                          lp["mlp"]["w_down"]), jnp.float32(0.0)

    # --------------------------------------------------------------- encoder

    def _encode(self, params, frontend: jax.Array) -> jax.Array:
        """Whisper encoder over stub frame embeddings (B, F, D)."""
        cfg = self.cfg
        h = frontend.astype(self.dtype)

        def enc_layer(h, lp):
            a = attention_bidir(rms_norm(h, lp["ln1"], cfg.norm_eps),
                                lp["attn"], cfg)
            h = h + a
            h = h + swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                           lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
            return constrain(h), None

        fn = jax.checkpoint(enc_layer) if cfg.remat else enc_layer
        h, _ = lax.scan(fn, h, params["encoder"])
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    # ---------------------------------------------------------- train forward

    def forward_train(self, params, batch: dict
                      ) -> tuple[jax.Array, jax.Array]:
        """batch: {"tokens": (B,S) int32, optional "frontend": (B,F,D)}.
        Returns (logits over the token positions, aux loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"][tokens]

        if cfg.arch_type == "encdec":
            enc_out = self._encode(params, batch["frontend"])

            def dec_layer(h, lp):
                a = attention_train(rms_norm(h, lp["ln1"], cfg.norm_eps),
                                    lp["attn"], cfg)
                h = h + a
                x = attention_cross(rms_norm(h, lp["ln_x"], cfg.norm_eps),
                                    lp["xattn"], cfg,
                                    *cross_kv(lp["xattn"], cfg, enc_out))
                h = h + x
                h, _ = self._mlp_or_moe(lp, h)
                return constrain(h), None

            fn = jax.checkpoint(dec_layer) if cfg.remat else dec_layer
            h, _ = lax.scan(fn, h, params["layers"])
            return self._logits(params, h), jnp.float32(0.0)

        prefix = 0
        if cfg.arch_type == "vlm":
            fe = batch["frontend"].astype(self.dtype)     # (B, P, D)
            prefix = fe.shape[1]
            h = jnp.concatenate([fe, h], axis=1)

        if cfg.arch_type in ("dense", "vlm", "moe"):
            def layer(h, lp):
                a = attention_train(rms_norm(h, lp["ln1"], cfg.norm_eps),
                                    lp["attn"], cfg, prefix_len=prefix)
                h = h + a
                h, aux = self._mlp_or_moe(lp, h)
                return constrain(h), aux

            fn = jax.checkpoint(layer) if cfg.remat else layer
            h, auxs = lax.scan(fn, h, params["layers"])
            logits = self._logits(params, h[:, prefix:] if prefix else h)
            return logits, jnp.sum(auxs)

        if cfg.arch_type == "ssm":
            def layer(h, lp):
                y, _ = ssm_block_train(rms_norm(h, lp["ln1"], cfg.norm_eps),
                                       lp["ssm"], cfg)
                return constrain(h + y), None

            fn = jax.checkpoint(layer) if cfg.remat else layer
            h, _ = lax.scan(fn, h, params["layers"])
            return self._logits(params, h), jnp.float32(0.0)

        if cfg.arch_type == "hybrid":
            h = self._hybrid_train(params, h)
            return self._logits(params, h), jnp.float32(0.0)

        raise ValueError(cfg.arch_type)

    def _hybrid_segments(self) -> tuple[int, int, int]:
        cfg = self.cfg
        every = cfg.attn_every or cfg.n_layers
        n_seg = cfg.n_layers // every
        rem = cfg.n_layers - n_seg * every
        return every, n_seg, rem

    def _hybrid_train(self, params, h):
        cfg = self.cfg
        every, n_seg, rem = self._hybrid_segments()

        def mamba_layer(h, lp):
            y, _ = ssm_block_train(rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   lp["ssm"], cfg)
            return constrain(h + y), None

        fn = jax.checkpoint(mamba_layer) if cfg.remat else mamba_layer
        layers = params["layers"]
        seg_layers = jax.tree.map(
            lambda a: a[: n_seg * every].reshape(n_seg, every, *a.shape[1:]),
            layers)
        sp = params["shared_attn"]
        for s in range(n_seg):
            seg = jax.tree.map(lambda a: a[s], seg_layers)
            h, _ = lax.scan(fn, h, seg)
            a = attention_train(rms_norm(h, sp["ln1"], cfg.norm_eps),
                                sp["attn"], cfg)
            h = h + a
            h = h + swiglu(rms_norm(h, sp["ln2"], cfg.norm_eps),
                           sp["mlp"]["w_gate"], sp["mlp"]["w_up"],
                           sp["mlp"]["w_down"])
        if rem:
            tail = jax.tree.map(lambda a: a[n_seg * every:], layers)
            h, _ = lax.scan(fn, h, tail)
        return h

    # ------------------------------------------------------------------ cache

    def init_cache(self, batch: int, slots: int, ring: bool = False,
                   enc_frames: int = 0):
        cfg, dt = self.cfg, self.dtype
        if cfg.arch_type in ("dense", "vlm", "moe"):
            return init_attn_cache(cfg.n_layers, batch, slots,
                                   cfg.n_kv_heads, cfg.head_dim, dt, ring)
        if cfg.arch_type == "ssm":
            from .ssm import conv_dim
            return init_ssm_cache(cfg.n_layers, batch, cfg.ssm_conv,
                                  conv_dim(cfg), cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state, dt)
        if cfg.arch_type == "hybrid":
            from .ssm import conv_dim
            _, n_seg, _ = self._hybrid_segments()
            return HybridCacheT(
                ssm=init_ssm_cache(cfg.n_layers, batch, cfg.ssm_conv,
                                   conv_dim(cfg), cfg.ssm_heads,
                                   cfg.ssm_head_dim, cfg.ssm_state, dt),
                shared_attn=init_attn_cache(max(1, n_seg), batch, slots,
                                            cfg.n_kv_heads, cfg.head_dim,
                                            dt, ring))
        if cfg.arch_type == "encdec":
            frames = enc_frames or cfg.n_frontend_tokens
            return EncDecCache(
                self_attn=init_attn_cache(cfg.n_layers, batch, slots,
                                          cfg.n_kv_heads, cfg.head_dim, dt,
                                          ring),
                cross_k=jnp.zeros((cfg.n_layers, batch, frames,
                                   cfg.n_kv_heads, cfg.head_dim), dt),
                cross_v=jnp.zeros((cfg.n_layers, batch, frames,
                                   cfg.n_kv_heads, cfg.head_dim), dt))
        raise ValueError(cfg.arch_type)

    def init_paged_cache(self, batch: int, length: int, n_blocks: int,
                         block_size: int, quantize: bool = False,
                         ring: bool = False) -> PagedAttnCache:
        """Paged serving cache: shared (L, n_blocks, block_size, Hkv, hd)
        pool + (batch, ceil(length/block_size)) block tables. Attention
        families only (dense/moe); recurrent state has no positions to
        page."""
        cfg, dt = self.cfg, self.dtype
        assert cfg.arch_type in ("dense", "moe"), (
            f"paged KV supports dense/moe, not {cfg.arch_type}")
        return init_paged_attn_cache(cfg.n_layers, batch, length, n_blocks,
                                     block_size, cfg.n_kv_heads,
                                     cfg.head_dim, dt, quantize=quantize,
                                     ring=ring)

    # ------------------------------------------------------- decode / verify

    def decode_step(self, params, token: jax.Array, cache, pos: jax.Array,
                    window: int = 0, uniform_pos: bool = False):
        """token: (B,) int32; pos: (B,). Returns (logits (B,V), cache)."""
        logits, cache = self._window_step(params, token[:, None], cache, pos,
                                          window, uniform_pos=uniform_pos)
        return logits[:, -1, :], cache

    def verify_step(self, params, window_tokens: jax.Array, cache,
                    pos: jax.Array, window: int = 0,
                    seq_lens: Optional[jax.Array] = None,
                    uniform_pos: bool = False,
                    slot_off: Optional[jax.Array] = None,
                    pos_off: Optional[jax.Array] = None,
                    win_mask: Optional[jax.Array] = None):
        """window_tokens: (B, T). Returns (logits (B,T,V), cache).
        ``seq_lens`` — right-padded batches (prefill): valid length per
        sequence; exact identity-masking for recurrent (SSM) state.
        ``slot_off``/``pos_off``/``win_mask`` — tree-speculation window
        layout (dense/moe attention caches only; see
        :func:`repro.models.attention.attention_decode`)."""
        return self._window_step(params, window_tokens, cache, pos, window,
                                 seq_lens, uniform_pos=uniform_pos,
                                 slot_off=slot_off, pos_off=pos_off,
                                 win_mask=win_mask)

    def _window_step(self, params, tokens: jax.Array, cache, pos: jax.Array,
                     window: int = 0, seq_lens: Optional[jax.Array] = None,
                     uniform_pos: bool = False,
                     slot_off: Optional[jax.Array] = None,
                     pos_off: Optional[jax.Array] = None,
                     win_mask: Optional[jax.Array] = None):
        cfg = self.cfg
        B, T = tokens.shape
        h = params["embed"][tokens]
        w = window or 0
        tree_args = (slot_off is not None or pos_off is not None
                     or win_mask is not None)
        if tree_args and (isinstance(cache, PagedAttnCache)
                          or cfg.arch_type not in ("dense", "vlm", "moe")):
            raise NotImplementedError(
                "tree-speculation windows need a dense/moe AttnCache")

        if isinstance(cache, PagedAttnCache):
            # block_table is shared by all layers: closed over, not scanned
            bt, ring_, length_ = cache.block_table, cache.ring, cache.length
            if cache.quantized:
                def player(h, inp):
                    lp, kc, vc, ks, vs, pm = inp
                    a, kc, vc, ks, vs, pm = attention_decode_paged(
                        rms_norm(h, lp["ln1"], cfg.norm_eps), lp["attn"],
                        cfg, kc, vc, ks, vs, pm, bt, pos, ring_, length_, w)
                    h = h + a
                    h, _ = self._mlp_or_moe(lp, h)
                    return h, (kc, vc, ks, vs, pm)

                h, (k, v, ks, vs, pm) = lax.scan(
                    player, h, (params["layers"], cache.k, cache.v,
                                cache.k_scale, cache.v_scale, cache.pos_map))
                new_cache = cache.replace(k=k, v=v, k_scale=ks, v_scale=vs,
                                          pos_map=pm)
            else:
                def player(h, inp):
                    lp, kc, vc, pm = inp
                    a, kc, vc, _, _, pm = attention_decode_paged(
                        rms_norm(h, lp["ln1"], cfg.norm_eps), lp["attn"],
                        cfg, kc, vc, None, None, pm, bt, pos, ring_,
                        length_, w)
                    h = h + a
                    h, _ = self._mlp_or_moe(lp, h)
                    return h, (kc, vc, pm)

                h, (k, v, pm) = lax.scan(
                    player, h,
                    (params["layers"], cache.k, cache.v, cache.pos_map))
                new_cache = cache.replace(k=k, v=v, pos_map=pm)
            return self._logits(params, h), new_cache

        if cfg.arch_type in ("dense", "vlm", "moe"):
            def layer(h, inp):
                lp, kc, vc, pm = inp
                a, kc, vc, pm = attention_decode(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
                    kc, vc, pm, pos, cache.ring, w, uniform_pos,
                    slot_off=slot_off, pos_off=pos_off, win_mask=win_mask)
                h = h + a
                h, _ = self._mlp_or_moe(lp, h)
                return h, (kc, vc, pm)

            h, (k, v, pm) = lax.scan(
                layer, h, (params["layers"], cache.k, cache.v, cache.pos_map))
            new_cache = AttnCache(k=k, v=v, pos_map=pm, ring=cache.ring)
            return self._logits(params, h), new_cache

        if cfg.arch_type == "ssm":
            return self._ssm_window(params, h, cache, T, seq_lens)

        if cfg.arch_type == "hybrid":
            return self._hybrid_window(params, h, cache, pos, T, w, seq_lens,
                                       uniform_pos)

        if cfg.arch_type == "encdec":
            def layer(h, inp):
                lp, kc, vc, pm, xk, xv = inp
                a, kc, vc, pm = attention_decode(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["attn"], cfg,
                    kc, vc, pm, pos, cache.self_attn.ring, w, uniform_pos)
                h = h + a
                x = attention_cross(rms_norm(h, lp["ln_x"], cfg.norm_eps),
                                    lp["xattn"], cfg, xk, xv)
                h = h + x
                h, _ = self._mlp_or_moe(lp, h)
                return h, (kc, vc, pm)

            sa = cache.self_attn
            h, (k, v, pm) = lax.scan(
                layer, h, (params["layers"], sa.k, sa.v, sa.pos_map,
                           cache.cross_k, cache.cross_v))
            new_cache = EncDecCache(
                self_attn=AttnCache(k=k, v=v, pos_map=pm, ring=sa.ring),
                cross_k=cache.cross_k, cross_v=cache.cross_v)
            return self._logits(params, h), new_cache

        raise ValueError(cfg.arch_type)

    def _ssm_window(self, params, h, cache: SSMCache, T: int,
                    seq_lens: Optional[jax.Array] = None):
        cfg = self.cfg

        if T == 1:
            def layer(h, inp):
                lp, conv, state = inp
                y, st = ssm_block_decode(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["ssm"], cfg,
                    SSDState(h=state, conv_tail=conv))
                return h + y, (st.conv_tail, st.h)
        else:
            def layer(h, inp):
                lp, conv, state = inp
                y, st = ssm_block_train(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["ssm"], cfg,
                    state=SSDState(h=state, conv_tail=conv),
                    seq_lens=seq_lens)
                return h + y, (st.conv_tail, st.h)

        h, (conv, state) = lax.scan(
            layer, h, (params["layers"], cache.conv, cache.state))
        return self._logits(params, h), SSMCache(conv=conv, state=state)

    def _hybrid_window(self, params, h, cache: HybridCacheT, pos, T: int,
                       w: int, seq_lens: Optional[jax.Array] = None,
                       uniform_pos: bool = False):
        cfg = self.cfg
        every, n_seg, rem = self._hybrid_segments()

        if T == 1:
            def mamba_layer(h, inp):
                lp, conv, state = inp
                y, st = ssm_block_decode(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["ssm"], cfg,
                    SSDState(h=state, conv_tail=conv))
                return h + y, (st.conv_tail, st.h)
        else:
            def mamba_layer(h, inp):
                lp, conv, state = inp
                y, st = ssm_block_train(
                    rms_norm(h, lp["ln1"], cfg.norm_eps), lp["ssm"], cfg,
                    state=SSDState(h=state, conv_tail=conv),
                    seq_lens=seq_lens)
                return h + y, (st.conv_tail, st.h)

        layers, ssm = params["layers"], cache.ssm
        sa, sp = cache.shared_attn, params["shared_attn"]
        seg = lambda a, s: jax.tree.map(
            lambda x: x[s * every:(s + 1) * every], a)
        convs, states = [], []
        ks, vs, pms = [], [], []
        for s in range(n_seg):
            h, (conv, state) = lax.scan(
                mamba_layer, h,
                (seg(layers, s), seg(ssm.conv, s), seg(ssm.state, s)))
            convs.append(conv)
            states.append(state)
            a, kc, vc, pm = attention_decode(
                rms_norm(h, sp["ln1"], cfg.norm_eps), sp["attn"], cfg,
                sa.k[s], sa.v[s], sa.pos_map[s], pos, sa.ring, w,
                uniform_pos)
            h = h + a
            h = h + swiglu(rms_norm(h, sp["ln2"], cfg.norm_eps),
                           sp["mlp"]["w_gate"], sp["mlp"]["w_up"],
                           sp["mlp"]["w_down"])
            ks.append(kc); vs.append(vc); pms.append(pm)
        if rem:
            tail = lambda a: jax.tree.map(lambda x: x[n_seg * every:], a)
            h, (conv, state) = lax.scan(
                mamba_layer, h,
                (tail(layers), tail(ssm.conv), tail(ssm.state)))
            convs.append(conv)
            states.append(state)
        new_cache = HybridCacheT(
            ssm=SSMCache(conv=jnp.concatenate(convs, axis=0),
                         state=jnp.concatenate(states, axis=0)),
            shared_attn=AttnCache(k=jnp.stack(ks), v=jnp.stack(vs),
                                  pos_map=jnp.stack(pms), ring=sa.ring))
        return self._logits(params, h), new_cache

    # ----------------------------------------------------------------- prefill

    def prefill(self, params, tokens: jax.Array, slots: int,
                frontend: Optional[jax.Array] = None, ring: bool = False,
                window: int = 0, prompt_lens: Optional[jax.Array] = None,
                chunk: Optional[int] = None, cache_shardings=None):
        """Process the whole prompt, build the serving cache.

        For attention families this routes through verify_step (cache-writing
        forward). For SSM/hybrid it runs the chunked scan. For encdec it also
        encodes the (stub) audio frames and precomputes cross-attention K/V.
        Returns (logits (B,S,V), cache).

        ``chunk``: long prompts process in ``chunk``-token pieces via a
        ``lax.scan`` with the cache as carry — attention scores stay
        O(chunk·S) instead of O(S²) (required for the 32k prefill shape).
        The chunked path returns logits for the LAST chunk only, shape
        (B, chunk, V) — serving needs just the anchor position."""
        cfg = self.cfg
        B, S = tokens.shape
        if cfg.arch_type != "ssm" and not ring and slots < S:
            # overflow writes are DROPPED, not clamped (models/kvcache.py):
            # refuse the geometry up front instead of silently losing the
            # prompt tail
            raise ValueError(
                f"prompt length {S} exceeds cache slots {slots}: size the "
                f"cache >= prompt + decode budget (or use a ring cache)")
        cache = self.init_cache(B, slots, ring=ring,
                                enc_frames=(frontend.shape[1]
                                            if frontend is not None and
                                            cfg.arch_type == "encdec" else 0))

        def pin(c):
            """Constrain the internally-built cache to the serving layout —
            without this XLA may replicate the batch dim of the scan-carried
            cache across the mesh (observed: an f32 full-cache temp)."""
            if cache_shardings is None:
                return c
            return jax.tree.map(
                lambda x, s: (jax.lax.with_sharding_constraint(x, s)
                              if isinstance(x, jax.Array) and hasattr(s, "spec")
                              else x),
                c, cache_shardings)

        cache = pin(cache)
        if cfg.arch_type == "encdec":
            enc_out = self._encode(params, frontend)

            def xkv(lp):
                return cross_kv(lp["xattn"], cfg, enc_out)
            xk, xv = jax.vmap(xkv)(params["layers"])
            cache = cache._replace(cross_k=xk, cross_v=xv)
        pos0 = jnp.zeros((B,), jnp.int32)
        if cfg.arch_type == "vlm" and frontend is not None:
            # Image prefix enters the cache first, then the text prompt.
            raise NotImplementedError(
                "vlm prefill with live frontend goes through serving.batching")
        if chunk and S > chunk and S % chunk == 0:
            assert prompt_lens is None, "chunked prefill takes full prompts"
            n = S // chunk
            blocks = jnp.moveaxis(tokens.reshape(B, n, chunk), 1, 0)

            def step(cache, inp):
                blk, idx = inp
                _, cache = self.verify_step(params, blk, cache,
                                            pos0 + idx * chunk, window,
                                            uniform_pos=True)
                return pin(cache), None

            cache, _ = lax.scan(step, cache,
                                (blocks[:-1], jnp.arange(n - 1)))
            # final chunk outside the scan so its logits survive; rewriting
            # its own cache slots is idempotent
            return self.verify_step(params, blocks[-1], cache,
                                    pos0 + (n - 1) * chunk, window,
                                    uniform_pos=True)
        return self.verify_step(params, tokens, cache, pos0, window,
                                seq_lens=prompt_lens)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
