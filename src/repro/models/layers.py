"""Shared neural-net layers: RMSNorm, rotary embeddings, SwiGLU, init."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key: jax.Array, shape: tuple[int, ...], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    fan_in = fan_in or shape[0]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    normed = x32 * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings (applied per absolute position; GQA-friendly)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, head_dim); positions: broadcastable to (..., T)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def causal_mask(q_len: int, kv_len: int, q_offset=0,
                window: int = 0) -> jax.Array:
    """(q_len, kv_len) boolean mask; True = attend. ``q_offset`` is the
    absolute position of query 0 relative to kv index 0. ``window`` > 0
    restricts to a sliding window of that many positions."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = k_pos <= q_pos
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    return mask
