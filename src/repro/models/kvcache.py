"""KV-cache structures.

A cache slot array carries an explicit ``pos_map`` of the absolute token
position written into each slot (−1 = empty). This one mechanism uniformly
handles:

- ordinary append-at-pos decode,
- **ring-buffer** caches for sliding-window serving (slot = pos % window) —
  the TPU-native way to serve `long_500k` with bounded VMEM/HBM footprint,
- **speculative rollback**: rejected window entries simply keep a pos_map
  greater than the committed position and are masked out of attention until
  overwritten (see models/attention.py), so no cache truncation pass is
  needed after a rejected speculation window.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AttnCache(NamedTuple):
    """Stacked over layers: k,v (L, B, S, Hkv, hd); pos_map (L, B, S)."""
    k: jax.Array
    v: jax.Array
    pos_map: jax.Array
    ring: bool = False        # static: slot = pos % S when True

    @property
    def slots(self) -> int:
        return self.k.shape[2]


def init_attn_cache(n_layers: int, batch: int, slots: int, n_kv: int,
                    head_dim: int, dtype, ring: bool = False) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        pos_map=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        ring=ring)


def update_layer_cache(k_cache: jax.Array, v_cache: jax.Array,
                       pos_map: jax.Array, k_new: jax.Array,
                       v_new: jax.Array, pos: jax.Array, ring: bool,
                       uniform_pos: bool = False
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Write a (B, T, Hkv, hd) window into one layer's cache at per-sequence
    positions ``pos`` (B,). Returns updated (k, v, pos_map).

    ``uniform_pos=True`` asserts all sequences share one position (aligned
    serving waves / chunked prefill): the write lowers to a
    ``dynamic_update_slice``, which GSPMD partitions cleanly — the general
    per-sequence scatter forces an involuntary resharding/replication of the
    cache inside the decode loop (XLA spmd_partitioner limitation) and is
    kept only for ragged engine batches."""
    B, T = k_new.shape[0], k_new.shape[1]
    S = k_cache.shape[1]
    if uniform_pos:
        p0 = pos[0]
        # no wrap handling: a T-token window must not straddle the ring seam
        # (serving guarantees T=1 for ring caches; see launch/shapes.py)
        slot0 = jnp.where(ring, p0 % S, jnp.minimum(p0, S - T))
        abs_pos = (p0 + jnp.arange(T))[None, :].astype(jnp.int32) \
            + jnp.zeros((B, 1), jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new, (0, slot0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new, (0, slot0, 0, 0))
        pos_map = jax.lax.dynamic_update_slice(pos_map, abs_pos, (0, slot0))
        return k_cache, v_cache, pos_map
    abs_pos = pos[:, None] + jnp.arange(T)[None, :]           # (B, T)
    slot = jnp.where(ring, abs_pos % S, jnp.minimum(abs_pos, S - 1))

    batch_idx = jnp.arange(B)[:, None].repeat(T, axis=1)      # (B, T)
    k_cache = k_cache.at[batch_idx, slot].set(k_new)
    v_cache = v_cache.at[batch_idx, slot].set(v_new)
    pos_map = pos_map.at[batch_idx, slot].set(abs_pos)
    return k_cache, v_cache, pos_map


class SSMCache(NamedTuple):
    """Mamba2 recurrent state, stacked over layers.

    conv:  (L, B, conv_width-1, conv_dim) — short-conv tail
    state: (L, B, n_heads, head_dim, d_state) — SSD state
    """
    conv: jax.Array
    state: jax.Array


def init_ssm_cache(n_layers: int, batch: int, conv_width: int, conv_dim: int,
                   n_heads: int, head_dim: int, d_state: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((n_layers, batch, conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((n_layers, batch, n_heads, head_dim, d_state),
                        jnp.float32))


class HybridCache(NamedTuple):
    """Zamba2-style hybrid: SSM cache for the backbone + one shared
    attention cache reused at each shared-block invocation site."""
    ssm: SSMCache
    attn: AttnCache
