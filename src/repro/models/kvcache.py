"""KV-cache structures.

A cache slot array carries an explicit ``pos_map`` of the absolute token
position written into each slot (−1 = empty). This one mechanism uniformly
handles:

- ordinary append-at-pos decode,
- **ring-buffer** caches for sliding-window serving (slot = pos % window) —
  the TPU-native way to serve `long_500k` with bounded VMEM/HBM footprint,
- **speculative rollback**: rejected window entries simply keep a pos_map
  greater than the committed position and are masked out of attention until
  overwritten (see models/attention.py), so no cache truncation pass is
  needed after a rejected speculation window.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AttnCache(NamedTuple):
    """Stacked over layers: k,v (L, B, S, Hkv, hd); pos_map (L, B, S)."""
    k: jax.Array
    v: jax.Array
    pos_map: jax.Array
    ring: bool = False        # static: slot = pos % S when True

    @property
    def slots(self) -> int:
        return self.k.shape[2]


def init_attn_cache(n_layers: int, batch: int, slots: int, n_kv: int,
                    head_dim: int, dtype, ring: bool = False) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        pos_map=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        ring=ring)


def update_layer_cache(k_cache: jax.Array, v_cache: jax.Array,
                       pos_map: jax.Array, k_new: jax.Array,
                       v_new: jax.Array, pos: jax.Array, ring: bool,
                       uniform_pos: bool = False
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Write a (B, T, Hkv, hd) window into one layer's cache at per-sequence
    positions ``pos`` (B,). Returns updated (k, v, pos_map).

    ``uniform_pos=True`` asserts all sequences share one position (aligned
    serving waves / chunked prefill): the write lowers to a
    ``dynamic_update_slice``, which GSPMD partitions cleanly — the general
    per-sequence scatter forces an involuntary resharding/replication of the
    cache inside the decode loop (XLA spmd_partitioner limitation) and is
    kept only for ragged engine batches."""
    B, T = k_new.shape[0], k_new.shape[1]
    S = k_cache.shape[1]
    if uniform_pos:
        p0 = pos[0]
        # no wrap handling: a T-token window must not straddle the ring seam
        # (serving guarantees T=1 for ring caches; see launch/shapes.py)
        slot0 = jnp.where(ring, p0 % S, jnp.minimum(p0, S - T))
        abs_pos = (p0 + jnp.arange(T))[None, :].astype(jnp.int32) \
            + jnp.zeros((B, 1), jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new, (0, slot0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new, (0, slot0, 0, 0))
        pos_map = jax.lax.dynamic_update_slice(pos_map, abs_pos, (0, slot0))
        return k_cache, v_cache, pos_map
    abs_pos = pos[:, None] + jnp.arange(T)[None, :]           # (B, T)
    slot = jnp.where(ring, abs_pos % S, jnp.minimum(abs_pos, S - 1))

    batch_idx = jnp.arange(B)[:, None].repeat(T, axis=1)      # (B, T)
    k_cache = k_cache.at[batch_idx, slot].set(k_new)
    v_cache = v_cache.at[batch_idx, slot].set(v_new)
    pos_map = pos_map.at[batch_idx, slot].set(abs_pos)
    return k_cache, v_cache, pos_map


class SSMCache(NamedTuple):
    """Mamba2 recurrent state, stacked over layers.

    conv:  (L, B, conv_width-1, conv_dim) — short-conv tail
    state: (L, B, n_heads, head_dim, d_state) — SSD state
    """
    conv: jax.Array
    state: jax.Array


def init_ssm_cache(n_layers: int, batch: int, conv_width: int, conv_dim: int,
                   n_heads: int, head_dim: int, d_state: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((n_layers, batch, conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((n_layers, batch, n_heads, head_dim, d_state),
                        jnp.float32))


class HybridCache(NamedTuple):
    """Zamba2-style hybrid: SSM cache for the backbone + one shared
    attention cache reused at each shared-block invocation site."""
    ssm: SSMCache
    attn: AttnCache


# --------------------------------------------------------------------------
# Slot recycling (continuous batching)
#
# A serving DecodeSession keeps ONE live cache of fixed batch capacity and
# recycles batch rows ("slots") across requests: a finished request's slot
# is retired and a new prompt's freshly prefilled cache row is inserted in
# its place, without touching neighbouring rows. Both helpers are jittable
# with a traced ``slot`` index, so admission/retirement never recompiles.
# --------------------------------------------------------------------------

def insert_slot(dst, src, slot, batch_axis: int = 1):
    """Write batch row 0 of every array leaf of ``src`` into batch row
    ``slot`` of the matching leaf of ``dst``.

    Works on any cache pytree (:class:`AttnCache`, :class:`SSMCache`,
    :class:`HybridCache`, encdec caches, full ``SpecDecodeState`` trees):
    layer-stacked leaves carry batch on ``batch_axis`` (L, B, ...); rank-1
    leaves (per-sequence scalars like ``pos``/``last_token``) carry it on
    axis 0. Non-array leaves (the static ``ring`` flag) keep ``dst``'s
    value. ``slot`` may be a traced int32 — the write lowers to
    ``dynamic_update_index_in_dim``, one compiled program for any slot."""
    def ins(d, s):
        if not isinstance(d, jax.Array) or d.ndim == 0:
            return d
        ax = batch_axis if d.ndim > batch_axis else 0
        row = jax.lax.index_in_dim(jnp.asarray(s), 0, axis=ax, keepdims=True)
        return jax.lax.dynamic_update_index_in_dim(
            d, row.astype(d.dtype), slot, axis=ax)
    return jax.tree.map(ins, dst, src)


def reset_slot(cache, slot, batch_axis: int = 1):
    """Scrub batch row ``slot`` of a cache pytree back to its init state:
    k/v/conv/state zeroed, ``pos_map`` re-filled with −1 (empty). Insertion
    already fully overwrites a slot, so this is hygiene for long-lived
    sessions (drops stale KV of retired requests) rather than a
    correctness requirement; the retire→re-admit tests assert both paths."""
    def _scrub(node):
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            vals = {}
            for name in node._fields:
                leaf = getattr(node, name)
                if isinstance(leaf, jax.Array) and leaf.ndim > 0:
                    ax = batch_axis if leaf.ndim > batch_axis else 0
                    fill = -1 if name == "pos_map" else 0
                    row = jnp.full_like(
                        jax.lax.index_in_dim(leaf, 0, axis=ax,
                                             keepdims=True), fill)
                    vals[name] = jax.lax.dynamic_update_index_in_dim(
                        leaf, row, slot, axis=ax)
                else:
                    vals[name] = _scrub(leaf) if isinstance(leaf, tuple) \
                        else leaf
            return type(node)(**vals)
        return node
    return _scrub(cache)
