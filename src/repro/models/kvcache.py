"""KV-cache structures: dense per-slot rows and the paged block pool.

Two attention-cache layouts share one masking mechanism:

- :class:`AttnCache` — the dense layout: every batch row ("slot") owns a
  max-length ``(L, B, S, Hkv, hd)`` allocation. Simple, and the reference
  the paged layout must match bit-for-bit, but capacity is priced at the
  worst-case sequence length even when most requests are short.
- :class:`PagedAttnCache` — the paged layout (vLLM-style): K/V live in a
  shared block pool ``(L, n_blocks, block_size, Hkv, hd)`` and each slot
  maps its *logical* positions ``0..length-1`` onto pool blocks through a
  per-slot int32 block table (``-1`` = unmapped). Admission allocates only
  the blocks a request's prompt + budget needs (:class:`BlockAllocator`);
  retirement frees them, so pool bytes buy admitted slots instead of
  padding. Optional int8 K/V halves block bytes again: each pool entry is
  quantized per (position, kv-head) over ``hd`` with the f32 scales stored
  alongside the blocks (``k_scale``/``v_scale``).

Both layouts carry an explicit ``pos_map`` of the absolute token position
written into each slot (dense) or pool entry (paged); ``-1`` = empty. This
one mechanism uniformly handles:

- ordinary append-at-pos decode,
- **ring-buffer** caches for sliding-window serving (logical slot =
  pos % length) — the TPU-native way to serve ``long_500k`` with bounded
  VMEM/HBM footprint,
- **speculative rollback**: rejected window entries simply keep a pos_map
  greater than the committed position and are masked out of attention until
  overwritten (see models/attention.py), so no cache truncation pass is
  needed after a rejected speculation window.

Speculative rollback × block reuse: the paged layout keeps rollback free
*only because* a slot's speculative window always lands inside its own
reserved blocks — admission reserves the full ``prompt + budget + 2γ``
footprint up front, so a rejected window never triggers an allocator call
and the stale entries are plain pos_map-masked pool entries. The converse
hazard is retirement: a retired slot's rows still receive (masked)
speculative window writes from the engine's frozen-slot step, so its block
table row MUST be scrubbed to ``-1`` (writes then drop) *before* its blocks
may be handed to another request — :func:`paged_release_slot`, dispatched
by ``DecodeSession.retire`` ahead of freeing the ids. Freed blocks may hold
stale pos_map entries; they are unreachable (no table points at them) and
the next insert fully rewrites pos_map for every block it maps.

Out-of-range writes (a sequence exceeding its cache/logical length) are
DROPPED in both layouts, never clamped: the dense non-ring path previously
clamped to the last slot, silently destroying the newest committed KV.
Callers are expected to size caches so this never fires (sessions
construct geometry from ``prompt + budget + 2γ + slack`` and assert it);
the drop is the safety net that keeps an overflow visible as a masked
(finite) error instead of silent corruption of a neighbour position.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AttnCache(NamedTuple):
    """Stacked over layers: k,v (L, B, S, Hkv, hd); pos_map (L, B, S)."""
    k: jax.Array
    v: jax.Array
    pos_map: jax.Array
    ring: bool = False        # static: slot = pos % S when True

    @property
    def slots(self) -> int:
        return self.k.shape[2]


def init_attn_cache(n_layers: int, batch: int, slots: int, n_kv: int,
                    head_dim: int, dtype, ring: bool = False) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, slots, n_kv, head_dim), dtype),
        pos_map=jnp.full((n_layers, batch, slots), -1, jnp.int32),
        ring=ring)


def update_layer_cache(k_cache: jax.Array, v_cache: jax.Array,
                       pos_map: jax.Array, k_new: jax.Array,
                       v_new: jax.Array, pos: jax.Array, ring,
                       uniform_pos: bool = False,
                       slot_off: Optional[jax.Array] = None,
                       pos_off: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Write a (B, T, Hkv, hd) window into one layer's cache at per-sequence
    positions ``pos`` (B,). Returns updated (k, v, pos_map).

    Non-ring writes past the cache edge (``pos + t >= S``) are DROPPED —
    the cache keeps its newest committed KV instead of silently overwriting
    the last slot (the old ``min(pos, S-1)`` clamp). Ring writes wrap by
    construction and cannot overflow.

    ``slot_off``/``pos_off`` (each (T,) int32, non-ring only) decouple the
    write slot (``pos + slot_off[t]``) from the stored logical position
    (``pos + pos_off[t]``) — tree speculation places sibling branches in
    distinct slots that share a position. Default (None) keeps slot ==
    position == ``pos + t``, the linear layout.

    ``uniform_pos=True`` asserts all sequences share one position (aligned
    serving waves / chunked prefill): the write lowers to a
    ``dynamic_update_slice``, which GSPMD partitions cleanly — the general
    per-sequence scatter forces an involuntary resharding/replication of the
    cache inside the decode loop (XLA spmd_partitioner limitation) and is
    kept only for ragged engine batches. Uniform positions make overflow
    all-or-nothing, so the guard is a ``lax.cond`` skipping the whole
    write."""
    B, T = k_new.shape[0], k_new.shape[1]
    S = k_cache.shape[1]
    if uniform_pos:
        assert slot_off is None and pos_off is None
        p0 = pos[0]
        # no wrap handling: a T-token window must not straddle the ring seam
        # (serving guarantees T=1 for ring caches; see launch/shapes.py)
        slot0 = jnp.where(ring, p0 % S, jnp.minimum(p0, S - T))
        abs_pos = (p0 + jnp.arange(T))[None, :].astype(jnp.int32) \
            + jnp.zeros((B, 1), jnp.int32)

        def _write(ops):
            kc, vc, pm = ops
            kc = jax.lax.dynamic_update_slice(kc, k_new, (0, slot0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v_new, (0, slot0, 0, 0))
            pm = jax.lax.dynamic_update_slice(pm, abs_pos, (0, slot0))
            return kc, vc, pm

        overflow = jnp.logical_and(jnp.logical_not(jnp.asarray(ring)),
                                   p0 + T > S)
        return jax.lax.cond(overflow, lambda ops: ops, _write,
                            (k_cache, v_cache, pos_map))
    if slot_off is not None or pos_off is not None:
        # ``ring`` may arrive as a traced scalar (sessions canonicalize the
        # static flag into an array); the static check only fires when the
        # flag is still concrete — ring sessions never reach the tree path
        # (the engine/session gates reject them first)
        assert not (isinstance(ring, bool) and ring), \
            "tree slot/pos decoupling needs a non-ring cache"
    s_off = jnp.arange(T) if slot_off is None else slot_off
    p_off = s_off if pos_off is None else pos_off
    abs_pos = pos[:, None] + p_off[None, :]                   # (B, T)
    # non-ring: an out-of-range position indexes past S and the scatter
    # drops it (mode="drop") instead of clamping onto slot S-1
    write_pos = pos[:, None] + s_off[None, :]
    slot = jnp.where(ring, write_pos % S, write_pos)

    batch_idx = jnp.arange(B)[:, None].repeat(T, axis=1)      # (B, T)
    k_cache = k_cache.at[batch_idx, slot].set(k_new, mode="drop")
    v_cache = v_cache.at[batch_idx, slot].set(v_new, mode="drop")
    pos_map = pos_map.at[batch_idx, slot].set(abs_pos, mode="drop")
    return k_cache, v_cache, pos_map


def _tree_commit_layer(k, v, pm, pos, path, n_acc, n_entries, d_max):
    """One layer of :func:`tree_commit_cache` — k/v (B,S,Hkv,hd), pm (B,S)."""
    B, S = pm.shape
    d_idx = jnp.arange(d_max)
    src = jnp.clip(pos[:, None] + path, 0, S - 1)             # (B, d_max)
    kg = jnp.take_along_axis(k, src[:, :, None, None], axis=1)
    vg = jnp.take_along_axis(v, src[:, :, None, None], axis=1)
    pg = jnp.take_along_axis(pm, src, axis=1)
    # Scrub the whole window region: losing branches AND stale tails; the
    # accepted path is re-scattered below. (Tree slots carry pos_map values
    # below their slot index, so the linear path's slot_pos<=q_pos masking
    # cannot be relied on here — the scrub makes staleness explicit.)
    s_idx = jnp.arange(S)[None, :]
    region = (s_idx > pos[:, None]) & (s_idx < pos[:, None] + n_entries)
    pm = jnp.where(region, -1, pm)
    valid = d_idx[None, :] < n_acc[:, None]
    dest = jnp.where(valid, pos[:, None] + 1 + d_idx[None, :], S)  # S ⇒ drop
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], dest.shape)
    k = k.at[b_idx, dest].set(kg, mode="drop")
    v = v.at[b_idx, dest].set(vg, mode="drop")
    # A source entry the proposer never wrote (pg < 0, the draft's tail
    # hole) stays a hole after relocation instead of validating garbage KV.
    new_pm = jnp.where(pg >= 0, pos[:, None] + 1 + d_idx[None, :], -1)
    pm = pm.at[b_idx, dest].set(new_pm, mode="drop")
    return k, v, pm


def tree_commit_cache(cache: AttnCache, pos: jax.Array, path: jax.Array,
                      n_acc: jax.Array, n_entries: int) -> AttnCache:
    """Relocate a verified tree's winning path onto the canonical linear
    slots and scrub the losers (dense non-ring caches only).

    Tree entry ``e`` lives at slot ``pos + e`` with logical position
    ``pos + tree_pos[e]`` — after the verdict, accepted depth ``d`` of the
    winning path (entry ``path[:, d]``, ``d < n_acc``) must end up where
    the linear layout keeps it: slot ``pos + 1 + d`` with pos_map
    ``pos + 1 + d``. Everything else in ``(pos, pos + n_entries)`` gets
    its pos_map scrubbed to −1 (the pos_map rollback mechanism, plus the
    relocation the linear path never needs because its slots == positions).

    ``path`` entries at ``d >= n_acc`` are ignored (dropped scatter); done
    rows pass ``n_acc == 0`` and only scrub."""
    assert not (isinstance(cache.ring, bool) and cache.ring), \
        "tree speculation needs a non-ring dense cache"
    d_max = path.shape[1]
    k, v, pm = jax.vmap(
        _tree_commit_layer, in_axes=(0, 0, 0, None, None, None, None, None)
    )(cache.k, cache.v, cache.pos_map, pos, path, n_acc, n_entries, d_max)
    return cache._replace(k=k, v=v, pos_map=pm)


# --------------------------------------------------------------------------
# Paged attention cache: shared block pool + per-slot block tables
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class PagedAttnCache:
    """Paged KV storage for the attention families (dense/moe).

    Pool leaves (shared across slots):

    - ``k``/``v``:   (L, n_blocks, block_size, Hkv, hd) — model dtype, or
      int8 when quantized,
    - ``k_scale``/``v_scale``: (L, n_blocks, block_size, Hkv) f32 dequant
      scales, present only when quantized,
    - ``pos_map``:   (L, n_blocks, block_size) int32 absolute positions
      (−1 = empty), the same masking contract as :class:`AttnCache`.

    Per-slot mapping:

    - ``block_table``: (B, n_log) int32, shared by all layers; entry
      ``[b, i]`` is the pool block holding slot ``b``'s logical positions
      ``[i·bs, (i+1)·bs)``, or −1 (unmapped ⇒ writes drop, reads mask).

    ``ring`` and ``length`` are STATIC aux data (hashable, part of the jit
    signature): ``length`` is the logical sequence capacity — gathering a
    slot's blocks in logical order and slicing to ``length`` reproduces a
    dense ``AttnCache`` row exactly, which is what makes the paged decode
    path bit-identical to the dense one (same reduction lengths, same
    masking; see models/attention.py)."""

    def __init__(self, k, v, pos_map, block_table, ring: bool = False,
                 length: int = 0, k_scale=None, v_scale=None):
        self.k = k
        self.v = v
        self.pos_map = pos_map
        self.block_table = block_table
        self.ring = bool(ring)
        self.length = int(length)
        self.k_scale = k_scale
        self.v_scale = v_scale

    # pytree protocol: pool/table leaves are children, geometry is static
    def tree_flatten(self):
        return ((self.k, self.v, self.pos_map, self.block_table,
                 self.k_scale, self.v_scale), (self.ring, self.length))

    @classmethod
    def tree_unflatten(cls, aux, children):
        k, v, pos_map, block_table, k_scale, v_scale = children
        ring, length = aux
        return cls(k=k, v=v, pos_map=pos_map, block_table=block_table,
                   ring=ring, length=length, k_scale=k_scale,
                   v_scale=v_scale)

    def replace(self, **kw) -> "PagedAttnCache":
        cur = dict(k=self.k, v=self.v, pos_map=self.pos_map,
                   block_table=self.block_table, ring=self.ring,
                   length=self.length, k_scale=self.k_scale,
                   v_scale=self.v_scale)
        cur.update(kw)
        return PagedAttnCache(**cur)

    @property
    def n_blocks(self) -> int:
        return self.k.shape[1]

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    @property
    def n_logical_blocks(self) -> int:
        return self.block_table.shape[1]

    @property
    def slots(self) -> int:           # AttnCache parity (logical length)
        return self.length

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def logical_blocks(length: int, block_size: int) -> int:
    """Blocks needed to cover ``length`` logical positions."""
    return math.ceil(length / block_size)


def init_paged_attn_cache(n_layers: int, batch: int, length: int,
                          n_blocks: int, block_size: int, n_kv: int,
                          head_dim: int, dtype, quantize: bool = False,
                          ring: bool = False) -> PagedAttnCache:
    n_log = logical_blocks(length, block_size)
    kv_dtype = jnp.int8 if quantize else dtype
    scale = (jnp.zeros((n_layers, n_blocks, block_size, n_kv), jnp.float32)
             if quantize else None)
    return PagedAttnCache(
        k=jnp.zeros((n_layers, n_blocks, block_size, n_kv, head_dim),
                    kv_dtype),
        v=jnp.zeros((n_layers, n_blocks, block_size, n_kv, head_dim),
                    kv_dtype),
        pos_map=jnp.full((n_layers, n_blocks, block_size), -1, jnp.int32),
        block_table=jnp.full((batch, n_log), -1, jnp.int32),
        ring=ring, length=length, k_scale=scale,
        v_scale=None if scale is None else jnp.zeros_like(scale))


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-entry symmetric int8 over the head dim: x (..., hd) →
    (int8 (..., hd), f32 scale (...,))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _flat_pool(pool: jax.Array):
    """(L, NB, bs, ...) → (L, NB·bs, ...) so (block, offset) pairs address
    entries through one fused index."""
    L, NB, bs = pool.shape[:3]
    return pool.reshape(L, NB * bs, *pool.shape[3:])


def paged_update_layer(k_pool: jax.Array, v_pool: jax.Array,
                       k_scale: Optional[jax.Array],
                       v_scale: Optional[jax.Array],
                       pos_map: jax.Array, block_table: jax.Array,
                       k_new: jax.Array, v_new: jax.Array, pos: jax.Array,
                       ring: bool, length: int):
    """Write a (B, T, Hkv, hd) window into ONE layer's pool through the
    block table. Pool leaves here are single-layer: k/v (NB, bs, Hkv, hd),
    pos_map (NB, bs).

    Logical slot = pos (ring: pos % length); the write scatters into
    ``block_table[b, slot // bs] · bs + slot % bs`` of the flattened pool.
    Writes to unmapped blocks (table −1) or past ``length`` are DROPPED —
    mirroring the dense overflow-drop semantics, so a paged slot and a
    dense row diverge on nothing."""
    B, T = k_new.shape[0], k_new.shape[1]
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    n_log = block_table.shape[1]
    abs_pos = pos[:, None] + jnp.arange(T)[None, :]           # (B, T)
    logical = jnp.where(ring, abs_pos % length, abs_pos)
    blk = logical // bs
    off = logical % bs
    phys = jnp.take_along_axis(block_table,
                               jnp.clip(blk, 0, n_log - 1), axis=1)
    invalid = (phys < 0) | (logical >= length) | (blk >= n_log)
    flat = jnp.where(invalid, NB * bs, phys * bs + off)       # OOB ⇒ drop

    if k_scale is not None:
        k_q, k_s = quantize_kv(k_new)
        v_q, v_s = quantize_kv(v_new)
        k_pool = _scatter_flat(k_pool, flat, k_q)
        v_pool = _scatter_flat(v_pool, flat, v_q)
        k_scale = _scatter_flat(k_scale, flat, k_s)
        v_scale = _scatter_flat(v_scale, flat, v_s)
    else:
        k_pool = _scatter_flat(k_pool, flat, k_new)
        v_pool = _scatter_flat(v_pool, flat, v_new)
    pm = pos_map.reshape(NB * bs)
    pm = pm.at[flat].set(abs_pos, mode="drop").reshape(NB, bs)
    return k_pool, v_pool, k_scale, v_scale, pm


def _scatter_flat(pool: jax.Array, flat: jax.Array, val: jax.Array):
    NB, bs = pool.shape[0], pool.shape[1]
    f = pool.reshape(NB * bs, *pool.shape[2:])
    return f.at[flat].set(val.astype(pool.dtype),
                          mode="drop").reshape(pool.shape)


def gather_layer_paged(k_pool: jax.Array, v_pool: jax.Array,
                       k_scale: Optional[jax.Array],
                       v_scale: Optional[jax.Array],
                       pos_map: jax.Array, block_table: jax.Array,
                       length: int, out_dtype):
    """Materialize ONE layer's logical dense view from the pool:
    k/v (B, length, Hkv, hd) in ``out_dtype`` plus pos (B, length).

    The view is position-ordered and sliced to exactly ``length`` entries,
    so downstream attention math is shape-identical (hence, for fp pools,
    bit-identical) to the dense path; unmapped positions read block 0 but
    surface pos −1 and are masked exactly like a dense empty slot."""
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    j = jnp.arange(length)
    phys = block_table[:, j // bs]                            # (B, length)
    flat = jnp.clip(phys, 0, NB - 1) * bs + (j % bs)[None, :]
    kf = k_pool.reshape(NB * bs, *k_pool.shape[2:])
    vf = v_pool.reshape(NB * bs, *v_pool.shape[2:])
    k_d = kf[flat]
    v_d = vf[flat]
    if k_scale is not None:
        ks = k_scale.reshape(NB * bs, -1)[flat]               # (B, len, Hkv)
        vs = v_scale.reshape(NB * bs, -1)[flat]
        k_d = (k_d.astype(jnp.float32) * ks[..., None]).astype(out_dtype)
        v_d = (v_d.astype(jnp.float32) * vs[..., None]).astype(out_dtype)
    else:
        k_d = k_d.astype(out_dtype)
        v_d = v_d.astype(out_dtype)
    pm_d = jnp.where(phys >= 0, pos_map.reshape(NB * bs)[flat], -1)
    return k_d, v_d, pm_d


def paged_insert_row(pool: PagedAttnCache, row: AttnCache,
                     block_ids: jax.Array, slot) -> PagedAttnCache:
    """Admission: scatter a freshly prefilled DENSE cache row (batch 1,
    S == pool.length) into the pool blocks ``block_ids`` ((n_log,) int32,
    −1 = unreserved tail) and point ``block_table[slot]`` at them.

    Every mapped block gets its k/v/pos_map fully rewritten (the padded
    row tail carries pos −1), so a reused block can never leak its previous
    tenant's entries — scrub-on-alloc. ``slot`` and ``block_ids`` may be
    traced (one compiled insert program for any slot/any blocks)."""
    L = row.k.shape[0]
    S = row.k.shape[2]
    NB, bs = pool.n_blocks, pool.block_size
    n_log = block_ids.shape[0]
    padS = n_log * bs
    assert S <= padS, (S, padS)

    def blocks_of(x, fill):
        x = x[:, 0]                                    # (L, S, ...)
        pad = [(0, 0), (0, padS - S)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, pad, constant_values=fill)
        return x.reshape(L, n_log, bs, *x.shape[2:])

    idx = jnp.where(block_ids >= 0, block_ids, NB)     # −1 ⇒ dropped write
    k_b = blocks_of(row.k, 0)
    v_b = blocks_of(row.v, 0)
    pm_b = blocks_of(row.pos_map, -1)
    if pool.quantized:
        k_b, ks_b = quantize_kv(k_b)
        v_b, vs_b = quantize_kv(v_b)
        k_scale = pool.k_scale.at[:, idx].set(ks_b, mode="drop")
        v_scale = pool.v_scale.at[:, idx].set(vs_b, mode="drop")
    else:
        k_scale, v_scale = pool.k_scale, pool.v_scale
    k = pool.k.at[:, idx].set(k_b.astype(pool.k.dtype), mode="drop")
    v = pool.v.at[:, idx].set(v_b.astype(pool.v.dtype), mode="drop")
    pm = pool.pos_map.at[:, idx].set(pm_b, mode="drop")
    table = pool.block_table.at[slot].set(block_ids.astype(jnp.int32))
    return pool.replace(k=k, v=v, pos_map=pm, block_table=table,
                        k_scale=k_scale, v_scale=v_scale)


def paged_release_slot(pool: PagedAttnCache, slot) -> PagedAttnCache:
    """Retirement: unmap a slot's block table row (−1 ⇒ the frozen slot's
    ongoing speculative window writes drop). MUST run before the slot's
    blocks are returned to the allocator — see the module docstring on
    block reuse."""
    n_log = pool.n_logical_blocks
    return pool.replace(block_table=pool.block_table.at[slot].set(
        jnp.full((n_log,), -1, jnp.int32)))


class BlockAllocator:
    """Host-side free-list allocator over the pool's physical blocks.

    Blocks are unit-sized so there is no external fragmentation; the
    invariants the property tests pin down are (a) a block is never handed
    to two live reservations, (b) free + allocated always partition
    ``[0, n_blocks)``, (c) ``alloc`` fails exactly when fewer than ``n``
    blocks are free. LIFO reuse keeps recently-touched blocks hot."""

    def __init__(self, n_blocks: int):
        self.n_blocks = int(n_blocks)
        self._free = list(range(self.n_blocks - 1, -1, -1))  # pop() → 0 first
        self._used: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} blocks, {len(self._free)} free "
                f"of {self.n_blocks}")
        ids = [self._free.pop() for _ in range(n)]
        self._used.update(ids)
        return ids

    def free(self, ids) -> None:
        for i in ids:
            i = int(i)
            if i < 0:
                continue               # padded (unreserved) table entries
            assert i in self._used, f"double free of block {i}"
            self._used.remove(i)
            self._free.append(i)


class SSMCache(NamedTuple):
    """Mamba2 recurrent state, stacked over layers.

    conv:  (L, B, conv_width-1, conv_dim) — short-conv tail
    state: (L, B, n_heads, head_dim, d_state) — SSD state
    """
    conv: jax.Array
    state: jax.Array


def init_ssm_cache(n_layers: int, batch: int, conv_width: int, conv_dim: int,
                   n_heads: int, head_dim: int, d_state: int, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((n_layers, batch, conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((n_layers, batch, n_heads, head_dim, d_state),
                        jnp.float32))


class HybridCache(NamedTuple):
    """Zamba2-style hybrid: SSM cache for the backbone + one shared
    attention cache reused at each shared-block invocation site."""
    ssm: SSMCache
    attn: AttnCache


# --------------------------------------------------------------------------
# Slot recycling (continuous batching)
#
# A serving DecodeSession keeps ONE live cache of fixed batch capacity and
# recycles batch rows ("slots") across requests: a finished request's slot
# is retired and a new prompt's freshly prefilled cache row is inserted in
# its place, without touching neighbouring rows. Both helpers are jittable
# with a traced ``slot`` index, so admission/retirement never recompiles.
# Paged caches recycle through block-map edits instead:
# paged_insert_row / paged_release_slot above.
# --------------------------------------------------------------------------

def insert_slot(dst, src, slot, batch_axis: int = 1):
    """Write batch row 0 of every array leaf of ``src`` into batch row
    ``slot`` of the matching leaf of ``dst``.

    Works on any cache pytree (:class:`AttnCache`, :class:`SSMCache`,
    :class:`HybridCache`, encdec caches, full ``SpecDecodeState`` trees):
    layer-stacked leaves carry batch on ``batch_axis`` (L, B, ...); rank-1
    leaves (per-sequence scalars like ``pos``/``last_token``) carry it on
    axis 0. Non-array leaves (the static ``ring`` flag) keep ``dst``'s
    value. ``slot`` may be a traced int32 — the write lowers to
    ``dynamic_update_index_in_dim``, one compiled program for any slot.
    Paged caches have mismatched pool/row structures and use
    :func:`paged_insert_row` instead."""
    def ins(d, s):
        if not isinstance(d, jax.Array) or d.ndim == 0:
            return d
        ax = batch_axis if d.ndim > batch_axis else 0
        row = jax.lax.index_in_dim(jnp.asarray(s), 0, axis=ax, keepdims=True)
        return jax.lax.dynamic_update_index_in_dim(
            d, row.astype(d.dtype), slot, axis=ax)
    return jax.tree.map(ins, dst, src)


def reset_slot(cache, slot, batch_axis: int = 1):
    """Scrub batch row ``slot`` of a cache pytree back to its init state:
    k/v/conv/state zeroed, ``pos_map`` re-filled with −1 (empty). Insertion
    already fully overwrites a slot, so this is hygiene for long-lived
    sessions (drops stale KV of retired requests) rather than a
    correctness requirement; the retire→re-admit tests assert both paths.
    Paged caches are left untouched here (their batch dim is the block
    table, handled by :func:`paged_release_slot`)."""
    def _scrub(node):
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            vals = {}
            for name in node._fields:
                leaf = getattr(node, name)
                if isinstance(leaf, jax.Array) and leaf.ndim > 0:
                    ax = batch_axis if leaf.ndim > batch_axis else 0
                    fill = -1 if name == "pos_map" else 0
                    row = jnp.full_like(
                        jax.lax.index_in_dim(leaf, 0, axis=ax,
                                             keepdims=True), fill)
                    vals[name] = jax.lax.dynamic_update_index_in_dim(
                        leaf, row, slot, axis=ax)
                else:
                    vals[name] = _scrub(leaf) if isinstance(leaf, tuple) \
                        else leaf
            return type(node)(**vals)
        return node
    return _scrub(cache)
