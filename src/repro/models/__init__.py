"""Model zoo — dense / MoE / SSM / hybrid / enc-dec / VLM, all JAX."""

from .model import Model, build_model
from .kvcache import (AttnCache, BlockAllocator, PagedAttnCache, SSMCache,
                      init_attn_cache, init_paged_attn_cache,
                      init_ssm_cache)
