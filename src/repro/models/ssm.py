"""Mamba2 / SSD (state-space duality) blocks  [arXiv:2405.21060].

Scalar-A-per-head SSD recurrence:

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t        (state: hd × N)
    y_t = C_t · h_t + D ⊙ x_t

Three compute paths, all numerically the same recurrence:

- :func:`ssd_chunked`   — training/prefill: chunked "quadratic-within,
  recurrent-across" algorithm (sub-quadratic in S, MXU-friendly intra-chunk
  matmuls; this is the paper's SSD duality and the shape the Pallas kernel
  ``kernels/ssd`` implements per chunk),
- :func:`ssd_decode_step` — O(1)-state single-token serving step (what makes
  `long_500k` native for SSM/hybrid archs),
- a pure ``lax.scan`` token-recurrence lives in ``kernels/ssd/ref.py`` as the
  oracle both are tested against.

Speculative-decoding note (DESIGN.md §Arch-applicability): verification
recomputes the window through :func:`ssd_chunked` from the window-start
state *without* committing it; the engine advances the state only over
accepted tokens — the SSM analogue of attention-cache rollback.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, rms_norm


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_state


def init_ssm_params(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    nh = cfg.ssm_heads
    st = cfg.ssm_state
    cd = conv_dim(cfg)
    ks = jax.random.split(key, 4)
    # in_proj emits [z(din), xBC(din+2N), dt(nh)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * st + nh), dtype, fan_in=d),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, cd), dtype, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, nh).astype(jnp.float32))),
        "norm": jnp.zeros((din,), dtype),
        "out_proj": dense_init(ks[2], (din, d), dtype, fan_in=din),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    din, st, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:din + din + 2 * st]
    dt = zxbcdt[..., -nh:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None):
    """Depthwise causal conv over time. xBC: (B,S,C); w: (K,C).
    ``tail``: (B,K-1,C) carry-in state. Returns (out, new_tail)."""
    K = w.shape[0]
    B, S, C = xBC.shape
    if tail is None:
        tail = jnp.zeros((B, K - 1, C), xBC.dtype)
    ext = jnp.concatenate([tail, xBC], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + ext[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)
    new_tail = ext[:, S:, :] if K > 1 else tail
    return out, new_tail


class SSDState(NamedTuple):
    h: jax.Array          # (B, nh, hd, N) float32
    conv_tail: jax.Array  # (B, K-1, conv_dim)


def ssd_chunk(x, Bm, Cm, dt, A, h_in):
    """One SSD chunk (the Pallas-kernel unit).

    x:  (B, L, nh, hd)   — inputs (post conv/split)
    Bm: (B, L, N), Cm: (B, L, N)   — shared across heads (n_groups = 1)
    dt: (B, L, nh) (already softplus'ed), A: (nh,) negative reals
    h_in: (B, nh, hd, N) float32
    Returns (y (B,L,nh,hd), h_out).
    """
    Bsz, L, nh, hd = x.shape
    la = A[None, None, :] * dt                      # (B,L,nh) log-decay ≤ 0
    Lc = jnp.cumsum(la, axis=1)                     # (B,L,nh)

    # inter-chunk: contribution of the carried-in state
    y_state = jnp.einsum("bln,bhdn->blhd", Cm.astype(jnp.float32), h_in) \
        * jnp.exp(Lc)[..., None]

    # intra-chunk quadratic form: w(t,s) = exp(Lc_t - Lc_s) for s ≤ t
    seg = Lc[:, :, None, :] - Lc[:, None, :, :]     # (B,t,s,nh)
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, ..., None]
    w = jnp.where(mask, jnp.exp(seg), 0.0)          # (B,t,s,nh)
    cb = jnp.einsum("btn,bsn->bts", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))         # (B,t,s)
    scores = cb[..., None] * w * dt[:, None, :, :]  # (B,t,s,nh)
    y_intra = jnp.einsum("btsh,bshd->bthd", scores, x.astype(jnp.float32))

    # state update across the chunk
    decay_out = jnp.exp(Lc[:, -1:, :] - Lc)         # (B,L,nh) exp(Σ_{r>s} la_r)
    contrib = jnp.einsum("blh,bln,blhd->bhdn",
                         decay_out * dt, Bm.astype(jnp.float32),
                         x.astype(jnp.float32))
    h_out = jnp.exp(Lc[:, -1, :])[..., None, None] * h_in + contrib
    return (y_state + y_intra), h_out


def ssd_chunked(x, Bm, Cm, dt, A, h_in, chunk: int):
    """Scan :func:`ssd_chunk` across S/chunk chunks. S must be a multiple of
    ``chunk`` (model.py pads). Shapes as in ssd_chunk with L = S."""
    Bsz, S, nh, hd = x.shape
    n_chunks = S // chunk

    def to_chunks(a):
        return a.reshape(Bsz, n_chunks, chunk, *a.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x), to_chunks(Bm), to_chunks(Cm), to_chunks(dt))

    def step(h, inp):
        xc, bc, cc, dtc = inp
        y, h = ssd_chunk(xc, bc, cc, dtc, A, h)
        return h, y

    h_out, ys = jax.lax.scan(step, h_in, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, nh, hd)
    return y, h_out


def ssd_decode_step(x, Bm, Cm, dt, A, h_in):
    """Single-token recurrence. x: (B,nh,hd); Bm,Cm: (B,N); dt: (B,nh)."""
    a = jnp.exp(A[None, :] * dt)                          # (B,nh)
    upd = jnp.einsum("bh,bn,bhd->bhdn", dt, Bm.astype(jnp.float32),
                     x.astype(jnp.float32))
    h = a[..., None, None] * h_in + upd
    y = jnp.einsum("bn,bhdn->bhd", Cm.astype(jnp.float32), h)
    return y, h


# --------------------------------------------------------------------------
# Full block (proj → conv → SSD → gated norm → out proj)
# --------------------------------------------------------------------------

def ssm_block_train(x: jax.Array, p: dict, cfg: ModelConfig,
                    state: Optional[SSDState] = None,
                    use_kernel: bool = False,
                    seq_lens: Optional[jax.Array] = None
                    ) -> tuple[jax.Array, SSDState]:
    """x: (B, S, D) → (y (B,S,D), final state). Sub-quadratic in S.

    ``seq_lens`` (B,) — right-padded batches: positions ≥ len are *identity*
    for the recurrence (dt masked to 0 ⇒ decay 1, contribution 0) and the
    conv tail is gathered at each sequence's true end, so the final state is
    exactly the state after the valid prefix.
    """
    B, S, D = x.shape
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)
    tail = state.conv_tail if state is not None else \
        jnp.zeros((B, cfg.ssm_conv - 1, xBC_raw.shape[-1]), x.dtype)
    xBC, new_tail = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"], tail)
    if seq_lens is not None:
        # per-seq conv tail: raw inputs at positions len-K+1 .. len-1
        ext = jnp.concatenate([tail, xBC_raw], axis=1)      # (B, K-1+S, C)
        K1 = cfg.ssm_conv - 1
        new_tail = jax.vmap(
            lambda e, l: jax.lax.dynamic_slice_in_dim(e, l, K1, axis=0)
        )(ext, seq_lens)
    xs = xBC[..., :cfg.ssm_d_inner].reshape(B, S, nh, hd)
    Bm = xBC[..., cfg.ssm_d_inner:cfg.ssm_d_inner + st]
    Cm = xBC[..., cfg.ssm_d_inner + st:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if seq_lens is not None:
        valid = (jnp.arange(S)[None, :] < seq_lens[:, None])[..., None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(p["A_log"])
    h_in = state.h if state is not None else \
        jnp.zeros((B, nh, hd, st), jnp.float32)

    # pad S to a chunk multiple
    chunk = min(cfg.ssm_chunk, S) or S
    pad = (-S) % chunk
    if pad:
        padspec = [(0, 0), (0, pad)]
        xs = jnp.pad(xs, padspec + [(0, 0), (0, 0)])
        Bm = jnp.pad(Bm, padspec + [(0, 0)])
        Cm = jnp.pad(Cm, padspec + [(0, 0)])
        dt = jnp.pad(dt, padspec + [(0, 0)])
    if use_kernel:
        from ..kernels.ssd.ops import ssd_chunked_kernel
        y, h = ssd_chunked_kernel(xs, Bm, Cm, dt, A, h_in, chunk)
    else:
        y, h = ssd_chunked(xs, Bm, Cm, dt, A, h_in, chunk)
    if pad:
        # dt is padded with zeros AFTER softplus ⇒ padded steps have decay
        # exp(A·0)=1 and contribution dt·B⊗x = 0: identity on the state, so
        # h is exact; only the (discarded) padded y rows are garbage.
        y = y[:, :S]
    y = y + (p["D"][None, None, :, None] * xs[:, :S].astype(jnp.float32))
    y = y.reshape(B, S, cfg.ssm_d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, SSDState(h=h, conv_tail=new_tail)


def ssm_block_decode(x: jax.Array, p: dict, cfg: ModelConfig,
                     state: SSDState) -> tuple[jax.Array, SSDState]:
    """Single-token step. x: (B, 1, D)."""
    B = x.shape[0]
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    # conv via explicit tail concat (width K): newest input last
    ext = jnp.concatenate([state.conv_tail, xBC], axis=1)     # (B, K, C)
    K = p["conv_w"].shape[0]
    out = jnp.einsum("bkc,kc->bc", ext[:, -K:].astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))
    xBC1 = jax.nn.silu(out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_tail = ext[:, 1:, :] if K > 1 else state.conv_tail

    xs = xBC1[..., :cfg.ssm_d_inner].reshape(B, nh, hd)
    Bm = xBC1[..., cfg.ssm_d_inner:cfg.ssm_d_inner + st]
    Cm = xBC1[..., cfg.ssm_d_inner + st:]
    dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_decode_step(xs, Bm, Cm, dts, A, state.h)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, cfg.ssm_d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, SSDState(h=h, conv_tail=new_tail)
