"""Repo-invariant static analysis + runtime sanitizers.

The stack's performance claims hang on invariants the design forces but
nothing used to machine-check:

- the compile-once masked-γ loop (PR 1/7) — zero recompiles across
  adaptive-γ / tree-shape / admission churn;
- donation safety on ``donate_argnums`` buffers (a donated buffer is dead
  the moment the call dispatches);
- byte-exact ``WindowMsg``/``VerdictMsg`` codecs (the multi-process
  transport seam serializes through them);
- full-duplex post/recv/discard ordering in pipelined speculation.

Two layers enforce them:

- :mod:`repro.analysis.lint` — an AST lint engine
  (``python -m repro.analysis.lint src``) with ``DSD0xx`` rules: traced-
  value leaks in jit-reachable code, donated-buffer reuse, wire-schema
  parity, Pallas interpret routing and grid-divisibility hygiene.
- :mod:`repro.analysis.sanitize` / :mod:`repro.analysis.protocol` —
  runtime sanitizers: :func:`compile_guard` (counts XLA backend compiles
  via jax's monitoring events; the one recompile counter every bench
  shares) and :class:`CheckedTransport` (validates the full-duplex
  protocol state machine per round id across the conformance matrix).

Imports here are lazy so the lint CLI stays jax-free (CI runs it before
installing heavyweight deps compile).
"""

from __future__ import annotations

_SANITIZE = ("CompileGuard", "RecompileError", "compile_guard",
             "install_compile_listener", "jit_cache_programs",
             "total_backend_compiles")
_PROTOCOL = ("CheckedTransport", "ProtocolViolation")

__all__ = list(_SANITIZE + _PROTOCOL)


def __getattr__(name: str):
    if name in _SANITIZE:
        from . import sanitize
        return getattr(sanitize, name)
    if name in _PROTOCOL:
        from . import protocol
        return getattr(protocol, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
