"""DSD lint rules.

- DSD001  traced-value leak in jit-reachable code (``int()``/``float()``/
          ``.item()``/``np.*``/Python ``if`` on a traced array inside a
          function reachable from a ``jax.jit``/``kernel_op`` entry point)
- DSD002  donated-buffer reuse after a ``donate_argnums`` call site
- DSD003  wire-schema parity (``encode_*``/``decode_*`` must cover every
          field of the matching ``*Msg`` dataclass; device pass-through
          fields opt out with a ``wire-passthrough`` comment)
- DSD004  Pallas interpret routing (every ``pallas_call`` wrapper passes
          ``interpret=`` and resolves it via ``resolve_interpret``)
- DSD005  Pallas grid divisibility (a ``//``-tiled grid requires a
          matching ``assert X % tile == 0`` in the wrapper)

All rules are pure-AST: nothing here imports jax, so the linter runs in
environments without the runtime stack.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterator

from .lint import Finding, ModuleInfo, Project, display_path, rule


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _own_nodes(func: ast.AST) -> Iterator[ast.AST]:
    """Walk a function's nodes, not descending into nested defs/classes."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _DEFS + (ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


@dataclasses.dataclass
class FuncInfo:
    module: ModuleInfo
    qualname: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    is_root: bool = False
    root_via: str = ""


def _is_package(mod: ModuleInfo) -> bool:
    return mod.path.name == "__init__.py"


def _resolve_from(mod: ModuleInfo, node: ast.ImportFrom) -> str:
    if node.level == 0:
        return node.module or ""
    parts = mod.name.split(".")
    drop = node.level - 1 if _is_package(mod) else node.level
    parts = parts[:len(parts) - drop] if drop <= len(parts) else []
    if node.module:
        parts = parts + node.module.split(".")
    return ".".join(parts)


def _import_table(mod: ModuleInfo) -> dict[str, str]:
    """Local binding name -> absolute dotted target it refers to."""
    table: dict[str, str] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    table[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    table[head] = head
        elif isinstance(node, ast.ImportFrom):
            base = _resolve_from(mod, node)
            for a in node.names:
                if a.name == "*":
                    continue
                target = f"{base}.{a.name}" if base else a.name
                table[a.asname or a.name] = target
    return table


def _full_name(d: str | None, imports: dict[str, str]) -> str | None:
    """Expand a dotted source name through the module's import aliases."""
    if not d:
        return None
    head, _, rest = d.partition(".")
    target = imports.get(head)
    if target is None:
        return d
    return f"{target}.{rest}" if rest else target


_JIT_SUFFIXES = (".jit", ".pjit")


def _is_jit_name(full: str | None) -> bool:
    return full is not None and (
        full in ("jit", "pjit", "kernel_op")
        or full.endswith(_JIT_SUFFIXES)
        or full.endswith(".kernel_op"))


def _decorator_is_jit(dec: ast.AST, imports: dict[str, str]) -> bool:
    if isinstance(dec, ast.Call):
        full = _full_name(_dotted(dec.func), imports)
        if _is_jit_name(full):
            return True
        if full is not None and full.endswith("partial"):
            return any(_is_jit_name(_full_name(_dotted(a), imports))
                       for a in dec.args)
        return False
    return _is_jit_name(_full_name(_dotted(dec), imports))


def _collect_functions(mod: ModuleInfo) -> list[FuncInfo]:
    imports = _import_table(mod)
    funcs: list[FuncInfo] = []

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _DEFS):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                info = FuncInfo(mod, qual, child)
                if any(_decorator_is_jit(d, imports)
                       for d in child.decorator_list):
                    info.is_root = True
                    info.root_via = f"@jit {qual}"
                funcs.append(info)
                visit(child, qual)
            elif isinstance(child, ast.ClassDef):
                cls_prefix = f"{prefix}.{child.name}" if prefix else child.name
                visit(child, cls_prefix)
            else:
                visit(child, prefix)

    visit(mod.tree, "")

    # jax.jit(fn, ...) call sites mark local fn(s) as entry points too.
    by_simple: dict[str, list[FuncInfo]] = {}
    for f in funcs:
        by_simple.setdefault(f.node.name, []).append(f)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            full = _full_name(_dotted(node.func), imports)
            if _is_jit_name(full) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name):
                    for f in by_simple.get(target.id, []):
                        f.is_root = True
                        f.root_via = f.root_via or f"jit({target.id}) call"
    return funcs


class _Index:
    """Project-wide function index + call-graph edges for reachability."""

    def __init__(self, project: Project):
        self.project = project
        self.funcs: list[FuncInfo] = []
        self.by_key: dict[tuple[str, str], list[FuncInfo]] = {}
        self.imports: dict[str, dict[str, str]] = {}
        for mod in project.modules.values():
            self.imports[mod.name] = _import_table(mod)
            for f in _collect_functions(mod):
                self.funcs.append(f)
                self.by_key.setdefault((mod.name, f.node.name), []).append(f)

    def _lookup_dotted(self, full: str) -> list[FuncInfo]:
        parts = full.split(".")
        if len(parts) < 2:
            return []
        modname, fname = ".".join(parts[:-1]), parts[-1]
        mod = self.project.resolve_module(modname)
        if mod is None:
            return []
        return self.by_key.get((mod.name, fname), [])

    def callees(self, f: FuncInfo) -> list[FuncInfo]:
        mod = f.module
        imports = self.imports[mod.name]
        out: list[FuncInfo] = []
        # nested defs are reachable with their parent (loop bodies etc.)
        for child in ast.iter_child_nodes(f.node):
            for sub in ast.walk(child):
                if isinstance(sub, _DEFS):
                    out.extend(self.by_key.get((mod.name, sub.name), []))
        for node in _own_nodes(f.node):
            name: str | None = None
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                name = node.id
            if not name:
                continue
            parts = name.split(".")
            if parts[0] in ("self", "cls"):
                out.extend(self.by_key.get((mod.name, parts[-1]), []))
                continue
            if len(parts) == 1:
                local = self.by_key.get((mod.name, name), [])
                if local:
                    out.extend(local)
                    continue
                target = imports.get(name)
                if target:
                    out.extend(self._lookup_dotted(target))
                continue
            full = _full_name(name, imports)
            if full:
                out.extend(self._lookup_dotted(full))
        return out

    def reachable_from_jit(self) -> dict[int, FuncInfo]:
        seen: dict[int, FuncInfo] = {}
        frontier = [f for f in self.funcs if f.is_root]
        for f in frontier:
            seen[id(f)] = f
        while frontier:
            nxt: list[FuncInfo] = []
            for f in frontier:
                for callee in self.callees(f):
                    if id(callee) not in seen:
                        callee.root_via = callee.root_via or f.root_via
                        seen[id(callee)] = callee
                        nxt.append(callee)
            frontier = nxt
        return seen


# ---------------------------------------------------------------------------
# DSD001 — traced-value leaks in jit-reachable code
# ---------------------------------------------------------------------------

_SAFE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding"}
_SAFE_CALLS = {"len", "isinstance", "hasattr", "getattr", "type",
               "issubclass", "callable", "repr", "str", "format", "id"}
# jax.* calls that do NOT return traced values
_NONTRACED_JAX = {
    "jax.jit", "jax.pjit", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.devices", "jax.local_devices", "jax.device_count",
    "jax.default_backend", "jax.named_scope", "jax.clear_caches",
    "jax.tree_util.tree_structure", "jax.eval_shape", "jax.ShapeDtypeStruct",
    "jax.random.PRNGKey",  # key objects never leak through int()/np.*
}


def _is_jax_producer(call: ast.Call, imports: dict[str, str]) -> bool:
    full = _full_name(_dotted(call.func), imports)
    if not full:
        return False
    if full in _NONTRACED_JAX or full.endswith(".astype"):
        return False
    return full == "jax" or full.startswith("jax.")


def _expr_traced(e: ast.AST, traced: set[str], imports: dict[str, str]) -> bool:
    if isinstance(e, ast.Name):
        return e.id in traced
    if isinstance(e, ast.Attribute):
        if e.attr in _SAFE_ATTRS:
            return False
        return _expr_traced(e.value, traced, imports)
    if isinstance(e, ast.Call):
        if _is_jax_producer(e, imports):
            return True
        if isinstance(e.func, ast.Name) and e.func.id in _SAFE_CALLS:
            return False
        return (_expr_traced(e.func, traced, imports)
                or any(_expr_traced(a, traced, imports) for a in e.args)
                or any(_expr_traced(k.value, traced, imports)
                       for k in e.keywords))
    if isinstance(e, ast.BinOp):
        return (_expr_traced(e.left, traced, imports)
                or _expr_traced(e.right, traced, imports))
    if isinstance(e, ast.UnaryOp):
        return _expr_traced(e.operand, traced, imports)
    if isinstance(e, ast.BoolOp):
        return any(_expr_traced(v, traced, imports) for v in e.values)
    if isinstance(e, ast.Compare):
        return (_expr_traced(e.left, traced, imports)
                or any(_expr_traced(c, traced, imports) for c in e.comparators))
    if isinstance(e, ast.IfExp):
        return any(_expr_traced(x, traced, imports)
                   for x in (e.test, e.body, e.orelse))
    if isinstance(e, ast.Subscript):
        return _expr_traced(e.value, traced, imports)
    if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
        return any(_expr_traced(x, traced, imports) for x in e.elts)
    if isinstance(e, ast.Starred):
        return _expr_traced(e.value, traced, imports)
    return False


def _static_test(test: ast.AST) -> bool:
    """True for tests that inspect identity/structure, not traced values."""
    if isinstance(test, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)
    if isinstance(test, ast.BoolOp):
        return all(_static_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _static_test(test.operand)
    return False


def _assign_targets(node: ast.AST) -> Iterator[str]:
    if isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            yield from _assign_targets(elt)
    elif isinstance(node, ast.Starred):
        yield from _assign_targets(node.value)


def _static_params(f: FuncInfo, imports: dict[str, str]) -> set[str]:
    """Param names jit treats as static: kernel_op(...) names,
    static_argnames, and the conventional interpret flag."""
    static = {"interpret", "self", "cls"}
    for dec in f.node.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        full = _full_name(_dotted(dec.func), imports) or ""
        names: list[ast.AST] = []
        if full.endswith("kernel_op"):
            names = list(dec.args)
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                names.extend(kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value])
        for n in names:
            if isinstance(n, ast.Constant) and isinstance(n.value, str):
                static.add(n.value)
    return static


class _LeakScan:
    def __init__(self, f: FuncInfo, imports: dict[str, str]):
        self.f = f
        self.imports = imports
        self.traced: set[str] = set()
        # params of a jit-reachable function carry traced arrays unless
        # declared static; they count for host-forcing checks (int()/
        # .item()/np.*) but not for the stricter if-on-traced check,
        # where scalar/flag params are routine.
        static = _static_params(f, imports)
        args = f.node.args
        params = [a.arg for a in (args.posonlyargs + args.args
                                  + args.kwonlyargs)]
        self.maybe: set[str] = {p for p in params if p not in static}
        self.findings: list[Finding] = []

    def _emit(self, node: ast.AST, what: str) -> None:
        path = display_path(self.f.module.path)
        via = f" (reachable via {self.f.root_via})" if self.f.root_via else ""
        self.findings.append(Finding(
            path, node.lineno, node.col_offset, "DSD001",
            f"{what} inside jit-compiled code in `{self.f.qualname}`{via}"))

    def _check_expr(self, expr: ast.AST | None) -> None:
        if expr is None:
            return
        wide = self.traced | self.maybe
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d in ("int", "float", "bool", "complex"):
                if any(_expr_traced(a, wide, self.imports)
                       for a in node.args):
                    self._emit(node, f"Python {d}() forces a traced value "
                                     "to the host")
                continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "tolist")
                    and _expr_traced(node.func.value, wide, self.imports)):
                self._emit(node, f".{node.func.attr}() on a traced value")
                continue
            full = _full_name(d, self.imports)
            if full and (full == "numpy" or full.startswith("numpy.")):
                if (any(_expr_traced(a, wide, self.imports)
                        for a in node.args)
                        or any(_expr_traced(k.value, wide, self.imports)
                               for k in node.keywords)):
                    self._emit(node, f"numpy call `{d}` on a traced value")

    def _mark(self, target: ast.AST) -> None:
        for name in _assign_targets(target):
            self.traced.add(name)

    def scan(self, stmts: list[ast.stmt]) -> None:
        for s in stmts:
            if isinstance(s, _DEFS + (ast.ClassDef,)):
                continue
            if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                self._check_expr(s.value)
                if s.value is not None and _expr_traced(
                        s.value, self.traced, self.imports):
                    targets = s.targets if isinstance(s, ast.Assign) \
                        else [s.target]
                    for t in targets:
                        self._mark(t)
            elif isinstance(s, (ast.If, ast.While)):
                self._check_expr(s.test)
                if (_expr_traced(s.test, self.traced, self.imports)
                        and not _static_test(s.test)):
                    self._emit(s, "Python control flow on a traced value "
                                  "(use lax.cond/jnp.where)")
                self.scan(s.body)
                self.scan(s.orelse)
            elif isinstance(s, ast.For):
                self._check_expr(s.iter)
                if _expr_traced(s.iter, self.traced, self.imports):
                    self._mark(s.target)
                self.scan(s.body)
                self.scan(s.orelse)
            elif isinstance(s, ast.With):
                for item in s.items:
                    self._check_expr(item.context_expr)
                self.scan(s.body)
            elif isinstance(s, ast.Try):
                self.scan(s.body)
                for h in s.handlers:
                    self.scan(h.body)
                self.scan(s.orelse)
                self.scan(s.finalbody)
            elif isinstance(s, ast.Return):
                self._check_expr(s.value)
                if s.value is not None and _expr_traced(
                        s.value, self.traced, self.imports):
                    pass  # returning traced values is the point of jit
            elif isinstance(s, ast.Expr):
                self._check_expr(s.value)
            elif isinstance(s, (ast.Assert, ast.Raise, ast.Delete)):
                for child in ast.iter_child_nodes(s):
                    self._check_expr(child)


@rule("DSD001")
def check_traced_leaks(project: Project) -> Iterator[Finding]:
    index = _Index(project)
    for f in index.reachable_from_jit().values():
        scan = _LeakScan(f, index.imports[f.module.name])
        scan.scan(f.node.body)
        yield from scan.findings


# ---------------------------------------------------------------------------
# DSD002 — donated-buffer reuse after a donate_argnums call site
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call) -> set[int] | None:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        vals: list[ast.AST]
        if isinstance(kw.value, (ast.Tuple, ast.List)):
            vals = list(kw.value.elts)
        else:
            vals = [kw.value]
        out = set()
        for v in vals:
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                out.add(v.value)
        return out
    return None


class _DonationScan:
    def __init__(self, f: FuncInfo, imports: dict[str, str]):
        self.f = f
        self.imports = imports
        self.donors: dict[str, set[int]] = {}
        self.dead: dict[str, int] = {}  # var -> donation line
        self.findings: list[Finding] = []

    def _emit(self, node: ast.AST, name: str, where: int) -> None:
        self.findings.append(Finding(
            display_path(self.f.module.path), node.lineno, node.col_offset,
            "DSD002",
            f"`{name}` reused after being donated at line {where} "
            f"(donate_argnums invalidates the buffer) in "
            f"`{self.f.qualname}`"))

    def _loads(self, stmt: ast.stmt) -> Iterator[ast.AST]:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                yield node
            elif isinstance(node, ast.Attribute) and isinstance(
                    node.ctx, ast.Load):
                yield node

    def scan(self, stmts: list[ast.stmt]) -> None:
        for s in stmts:
            if isinstance(s, _DEFS + (ast.ClassDef,)):
                continue
            # 1. any read of a dead buffer?
            for node in self._loads(s):
                key = node.id if isinstance(node, ast.Name) else _dotted(node)
                if key in self.dead:
                    self._emit(node, key, self.dead[key])
                    del self.dead[key]  # report each donation once
            # 2. donating call sites kill their donated args
            for node in ast.walk(s):
                if not isinstance(node, ast.Call):
                    continue
                full = _full_name(_dotted(node.func), self.imports)
                if _is_jit_name(full):
                    pos = _donated_positions(node)
                    if pos and isinstance(s, ast.Assign):
                        for t in s.targets:
                            if isinstance(t, ast.Name):
                                self.donors[t.id] = pos
                    continue
                name = _dotted(node.func)
                if name in self.donors:
                    for i in self.donors[name]:
                        if i < len(node.args):
                            key = _dotted(node.args[i])
                            if key:
                                self.dead[key] = node.lineno
            # 3. reassignment revives the name
            if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = s.targets if isinstance(s, ast.Assign) else [s.target]
                for t in targets:
                    for name in _assign_targets(t):
                        self.dead.pop(name, None)
                    key = _dotted(t)
                    if key:
                        self.dead.pop(key, None)
            # recurse into compound statements sharing state (overapprox)
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(s, attr, None)
                if isinstance(sub, list) and sub and isinstance(
                        sub[0], ast.stmt):
                    self.scan(sub)
            for h in getattr(s, "handlers", []):
                self.scan(h.body)


@rule("DSD002")
def check_donation_reuse(project: Project) -> Iterator[Finding]:
    for mod in project.modules.values():
        imports = _import_table(mod)
        for f in _collect_functions(mod):
            scan = _DonationScan(f, imports)
            scan.scan(f.node.body)
            yield from scan.findings


# ---------------------------------------------------------------------------
# DSD003 — wire-schema parity
# ---------------------------------------------------------------------------

def _snake(name: str) -> str:
    return re.sub(r"(?<!^)(?=[A-Z])", "_", name).lower()


def _msg_classes(mod: ModuleInfo) -> Iterator[tuple[ast.ClassDef, list[str],
                                                    set[str]]]:
    for node in mod.tree.body:
        if not isinstance(node, ast.ClassDef) or not node.name.endswith("Msg"):
            continue
        is_dc = any(
            (_dotted(d) or _dotted(getattr(d, "func", ast.Pass())) or "")
            .split(".")[-1] == "dataclass"
            for d in node.decorator_list)
        if not is_dc:
            continue
        fields: list[str] = []
        passthrough: set[str] = set()
        for item in node.body:
            if isinstance(item, ast.AnnAssign) and isinstance(
                    item.target, ast.Name):
                fields.append(item.target.id)
                if "wire-passthrough" in mod.source_line(item.lineno):
                    passthrough.add(item.target.id)
        yield node, fields, passthrough


@rule("DSD003")
def check_wire_parity(project: Project) -> Iterator[Finding]:
    for mod in project.modules.values():
        path = display_path(mod.path)
        top_funcs = {n.name: n for n in mod.tree.body if isinstance(n, _DEFS)}
        for cls, fields, passthrough in _msg_classes(mod):
            stem = _snake(cls.name[:-len("Msg")])
            enc = top_funcs.get(f"encode_{stem}")
            dec = top_funcs.get(f"decode_{stem}")
            if enc is None and dec is None:
                continue  # not a wire type
            required = [f for f in fields if f not in passthrough]
            if enc is None:
                yield Finding(path, cls.lineno, cls.col_offset, "DSD003",
                              f"`{cls.name}` has decode_{stem} but no "
                              f"encode_{stem}")
            else:
                arg = enc.args.args[0].arg if enc.args.args else None
                seen = {n.attr for n in ast.walk(enc)
                        if isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id == arg}
                for f in required:
                    if f not in seen:
                        yield Finding(
                            path, enc.lineno, enc.col_offset, "DSD003",
                            f"encode_{stem} does not serialize "
                            f"`{cls.name}.{f}` (mark wire-passthrough if "
                            f"intentionally device-local)")
            if dec is None:
                yield Finding(path, cls.lineno, cls.col_offset, "DSD003",
                              f"`{cls.name}` has encode_{stem} but no "
                              f"decode_{stem}")
            else:
                ctor = None
                for n in ast.walk(dec):
                    if isinstance(n, ast.Call) and (
                            _dotted(n.func) or "").split(".")[-1] == cls.name:
                        ctor = n
                        break
                if ctor is None:
                    yield Finding(path, dec.lineno, dec.col_offset, "DSD003",
                                  f"decode_{stem} never constructs "
                                  f"`{cls.name}`")
                    continue
                provided = set(fields[:len(ctor.args)])
                provided |= {kw.arg for kw in ctor.keywords if kw.arg}
                for f in required:
                    if f not in provided:
                        yield Finding(
                            path, ctor.lineno, ctor.col_offset, "DSD003",
                            f"decode_{stem} does not reconstruct "
                            f"`{cls.name}.{f}`")
        yield from _check_frame_tables(mod, path)


def _check_frame_tables(mod: ModuleInfo, path: str) -> Iterator[Finding]:
    """Length-prefix framing parity: a module declaring ``FRAME_*`` kind
    constants (the socket transport's frame-kind tags) must route EVERY
    kind through both codec tables — a kind missing from
    ``FRAME_ENCODERS``/``FRAME_DECODERS`` is a frame the wire can carry
    but one side cannot (de)serialize."""
    consts: dict[str, ast.Assign] = {}
    tables: dict[str, ast.Assign] = {}
    for node in mod.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        name = node.targets[0].id
        if re.fullmatch(r"FRAME_[A-Z_]+", name) \
                and name not in ("FRAME_ENCODERS", "FRAME_DECODERS") \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            consts[name] = node
        elif name in ("FRAME_ENCODERS", "FRAME_DECODERS") \
                and isinstance(node.value, ast.Dict):
            tables[name] = node
    if not consts:
        return
    for tbl in ("FRAME_ENCODERS", "FRAME_DECODERS"):
        node = tables.get(tbl)
        if node is None:
            first = min(consts.values(), key=lambda n: n.lineno)
            yield Finding(path, first.lineno, first.col_offset, "DSD003",
                          f"module declares frame kinds "
                          f"{sorted(consts)} but no {tbl} codec table")
            continue
        keys = {k.id for k in node.value.keys if isinstance(k, ast.Name)}
        for name in sorted(set(consts) - keys):
            yield Finding(path, node.lineno, node.col_offset, "DSD003",
                          f"{tbl} does not route frame kind {name} — a "
                          f"framed message of that kind cannot cross the "
                          f"wire")


# ---------------------------------------------------------------------------
# DSD004 / DSD005 — Pallas kernel hygiene
# ---------------------------------------------------------------------------

def _pallas_calls(f: FuncInfo) -> list[ast.Call]:
    return [n for n in _own_nodes(f.node)
            if isinstance(n, ast.Call)
            and (_dotted(n.func) or "").split(".")[-1] == "pallas_call"]


def _grid_exprs(f: FuncInfo) -> list[ast.AST]:
    """grid= expressions fed to pallas_call or a *GridSpec, with one level
    of local-variable indirection resolved."""
    assigns: dict[str, ast.AST] = {}
    for n in _own_nodes(f.node):
        if isinstance(n, ast.Assign) and n.value is not None:
            for t in n.targets:
                if isinstance(t, ast.Name):
                    assigns[t.id] = n.value
    out: list[ast.AST] = []
    for n in _own_nodes(f.node):
        if not isinstance(n, ast.Call):
            continue
        callee = (_dotted(n.func) or "").split(".")[-1]
        if callee != "pallas_call" and not callee.endswith("GridSpec"):
            continue
        for kw in n.keywords:
            if kw.arg == "grid":
                expr = kw.value
                if isinstance(expr, ast.Name) and expr.id in assigns:
                    expr = assigns[expr.id]
                out.append(expr)
    return out


@rule("DSD004")
def check_pallas_interpret(project: Project) -> Iterator[Finding]:
    for mod in project.modules.values():
        path = display_path(mod.path)
        for f in _collect_functions(mod):
            calls = _pallas_calls(f)
            if not calls:
                continue
            resolves = any(
                isinstance(n, ast.Call)
                and (_dotted(n.func) or "").split(".")[-1]
                == "resolve_interpret"
                for n in _own_nodes(f.node))
            for call in calls:
                kwargs = {kw.arg for kw in call.keywords}
                if "interpret" not in kwargs:
                    yield Finding(
                        path, call.lineno, call.col_offset, "DSD004",
                        f"pallas_call in `{f.qualname}` does not pass "
                        f"interpret= (route through kernel_op/"
                        f"resolve_interpret)")
                elif not resolves:
                    yield Finding(
                        path, call.lineno, call.col_offset, "DSD004",
                        f"`{f.qualname}` passes interpret= without calling "
                        f"resolve_interpret() first")


@rule("DSD005")
def check_pallas_grid_divisibility(project: Project) -> Iterator[Finding]:
    for mod in project.modules.values():
        path = display_path(mod.path)
        for f in _collect_functions(mod):
            calls = _pallas_calls(f)
            if not calls:
                continue
            tiled = any(
                isinstance(sub, ast.BinOp)
                and isinstance(sub.op, ast.FloorDiv)
                for g in _grid_exprs(f) for sub in ast.walk(g))
            if not tiled:
                continue
            has_assert = any(
                isinstance(n, ast.Assert)
                and any(isinstance(sub, ast.BinOp)
                        and isinstance(sub.op, ast.Mod)
                        for sub in ast.walk(n.test))
                for n in _own_nodes(f.node))
            if not has_assert:
                yield Finding(
                    path, calls[0].lineno, calls[0].col_offset, "DSD005",
                    f"`{f.qualname}` tiles its grid with `//` but has no "
                    f"divisibility assert (`assert X % tile == 0`)")
