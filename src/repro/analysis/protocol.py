"""Full-duplex transport protocol race detector.

:class:`CheckedTransport` wraps any :class:`repro.distributed.transport.
Transport` and validates the pipelined speculation protocol as a state
machine over round ids, raising :class:`ProtocolViolation` at the first
out-of-order operation instead of letting a race silently corrupt the
decode:

- a window round id is posted at most once;
- ``recv_window`` requires a window in flight (no blind dequeue);
- a verdict may only be posted for a round whose window the target
  actually received, and only once (no verdict-before-window, no
  double-verdict);
- ``recv_verdict`` requires a verdict in flight;
- ``discard_window`` may only drop an in-flight *speculative* window
  (the optimistic next-round draft a miss superseded);
- :meth:`CheckedTransport.assert_drained` certifies that nothing is left
  on the wire — i.e. every superseded speculative window was discarded.

The wrapper is behavior-transparent: every check runs before delegating
to the wrapped transport's own primitives, delay/RTT/byte accounting is
untouched, and everything else (``wall_clock``, ``recent_rtt_ms``,
``control_roundtrip``, ...) passes straight through. The conformance
matrix (``tests/conformance/``) runs every real-path scenario through it.
"""

from __future__ import annotations

from collections import deque


class ProtocolViolation(AssertionError):
    """The full-duplex window/verdict protocol was driven out of order."""


try:
    # jax-free by design: repro.distributed.wire only needs numpy + the sim
    # payload models, so the checker can translate transport-level protocol
    # errors without dragging the transport/worker stack (and jax) in.
    from ..distributed.wire import TransportProtocolError as _TransportError
except Exception:  # pragma: no cover - keeps the checker importable alone
    class _TransportError(Exception):
        """Placeholder when repro.distributed is unavailable."""


class CheckedTransport:
    """Protocol-validating proxy around a Transport instance."""

    def __init__(self, inner):
        self._inner = inner
        self._windows: deque = deque()       # (round_id, speculative) in flight
        self._verdicts: deque = deque()      # round ids in flight
        self._window_rounds: set = set()     # every round id ever posted
        self._window_received: set = set()   # received, awaiting verdict
        self._verdict_posted: set = set()
        self.checked_ops = 0

    def _delegate(self, fn, *args):
        """Run an inner-transport primitive; a transport-level protocol
        error (empty-stream recv, malformed frame, peer hangup) is the
        same class of bug this checker exists to catch — re-raise it as a
        :class:`ProtocolViolation` so the suite fails at the call site."""
        try:
            return fn(*args)
        except _TransportError as e:
            raise ProtocolViolation(f"transport protocol error: {e}") from e

    # -- checked protocol surface -------------------------------------------

    def post_window(self, msg):
        self.checked_ops += 1
        rid = msg.round_id
        if rid in self._window_rounds:
            raise ProtocolViolation(
                f"window round {rid} posted twice (round ids must be unique "
                f"per stream)")
        self._window_rounds.add(rid)
        self._windows.append((rid, bool(msg.speculative)))
        return self._delegate(self._inner.post_window, msg)

    def _check_recv_window(self) -> None:
        self.checked_ops += 1
        if not self._windows:
            raise ProtocolViolation(
                "recv_window with no window in flight (double-recv or "
                "recv-before-post)")
        rid, _spec = self._windows.popleft()
        self._window_received.add(rid)

    def recv_window(self):
        self._check_recv_window()
        return self._delegate(self._inner.recv_window)

    def post_verdict(self, msg):
        self.checked_ops += 1
        rid = msg.round_id
        if rid in self._verdict_posted:
            raise ProtocolViolation(f"verdict for round {rid} posted twice")
        if rid not in self._window_received:
            raise ProtocolViolation(
                f"verdict for round {rid} posted before its window was "
                f"received (windows seen: {sorted(self._window_received)})")
        self._verdict_posted.add(rid)
        self._verdicts.append(rid)
        return self._delegate(self._inner.post_verdict, msg)

    def _check_recv_verdict(self) -> None:
        self.checked_ops += 1
        if not self._verdicts:
            raise ProtocolViolation(
                "recv_verdict with no verdict in flight (double-recv or "
                "recv-before-post)")
        self._verdicts.popleft()

    def recv_verdict(self):
        self._check_recv_verdict()
        return self._delegate(self._inner.recv_verdict)

    def discard_window(self):
        self.checked_ops += 1
        if not self._windows:
            raise ProtocolViolation("discard_window with no window in flight")
        rid, spec = self._windows.popleft()
        if not spec:
            raise ProtocolViolation(
                f"discard_window dropped NON-speculative window round {rid} "
                f"— only superseded optimistic drafts may be discarded")
        return self._delegate(self._inner.discard_window)

    # half-duplex convenience paths: same checks, same base-class semantics
    def send_window(self, msg):
        self.post_window(msg)
        self._check_recv_window()
        return self._delegate(self._inner._recv, _FWD)[1]

    def send_verdict(self, msg):
        self.post_verdict(msg)
        self._check_recv_verdict()
        return self._delegate(self._inner._recv, _BWD)[1]

    # -- certification -------------------------------------------------------

    def assert_drained(self) -> None:
        """No window/verdict may remain in flight: every speculative
        window a miss superseded must have been discarded, every verdict
        consumed. Call at chunk/run boundaries."""
        if self._windows:
            rounds = [rid for rid, _ in self._windows]
            raise ProtocolViolation(
                f"undrained windows in flight for rounds {rounds} — "
                f"superseded speculative window never discarded")
        if self._verdicts:
            raise ProtocolViolation(
                f"undrained verdicts in flight for rounds "
                f"{list(self._verdicts)}")

    # -- transparency --------------------------------------------------------

    def describe(self) -> str:
        return self._inner.describe()

    def __getattr__(self, name):
        return getattr(self._inner, name)


# queue direction keys of repro.distributed.transport, duplicated here so
# importing the checker never drags the transport stack (and jax) in
_FWD = "window"
_BWD = "verdict"
