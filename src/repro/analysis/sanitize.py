"""Runtime recompile sentry.

The engine's compile-once contract (PR 1: one jitted masked-γ step per
``(gamma_max[, b_max])`` shape family; PR 7: one tree program across the
whole (γ, b) grid) used to be re-checked per bench with ad-hoc
``engine.compiled_programs()`` deltas. This module is the one shared
counter: a process-global listener on jax's monitoring events counts
actual XLA backend compilations, and :func:`compile_guard` turns "this
region must not compile" into a context manager that raises on exit.

Two counters, two purposes:

- :func:`total_backend_compiles` — backend compiles since the listener
  was installed. What :func:`compile_guard` snapshots; also what
  ``tests/conftest.py`` reports when the jit-cache teardown workaround
  is disabled.
- :func:`jit_cache_programs` — traced-program count of an explicit jit
  cache (the engine's ``_jit_cache``). Per-engine, survives unrelated
  compiles elsewhere in the process; what ``engine.compiled_programs()``
  delegates to.
"""

from __future__ import annotations

import threading

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_installed = False
_count = 0


def _on_event(event: str, duration: float, **kwargs) -> None:
    global _count
    if event == _COMPILE_EVENT:
        with _lock:
            _count += 1


def install_compile_listener() -> None:
    """Idempotently hook jax's monitoring stream. jax offers no
    unregistration, so one process-global listener is installed once and
    guards snapshot the counter instead of adding/removing hooks."""
    global _installed
    with _lock:
        if _installed:
            return
        _installed = True
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_event)


def total_backend_compiles() -> int:
    """XLA backend compilations observed since the listener was installed
    (0 compiles before :func:`install_compile_listener` are invisible —
    install early, e.g. at bench/conftest import)."""
    install_compile_listener()
    return _count


def jit_cache_programs(fns) -> int:
    """Total traced programs across an iterable of jitted callables (an
    engine's ``_jit_cache.values()``)."""
    total = 0
    for fn in fns:
        try:
            total += fn._cache_size()
        except Exception:  # pragma: no cover — older jax without _cache_size
            total += 1
    return total


class RecompileError(RuntimeError):
    """A guarded region compiled more XLA programs than it declared."""


class CompileGuard:
    """Context manager asserting a bounded number of compiles.

    ``allowed`` is the number of compilations the region may perform
    (0 for steady-state regions: everything must already be warm;
    ``None`` to only count — benches that *report* recompiles instead of
    crashing). ``.count`` is live inside the region; on a clean exit the
    guard raises :class:`RecompileError` iff ``count > allowed``. An
    exception already propagating out of the region takes precedence.

    Without ``track``, ``.count`` is the process-global backend-compile
    delta — the strictest sentry (any XLA compilation anywhere counts).
    With ``track=[engine, ...]`` (objects exposing ``compiled_programs()``),
    ``.count`` is the tracked engines' program-count delta instead: the
    compile-ONCE invariant on the decode step programs specifically,
    insensitive to incidental host-side utility jits (a ``jnp.mean`` over
    a fresh shape between measured cells compiles a one-op program that
    is not a step recompile). Benches gate on tracked counts and can
    still report :attr:`backend_compiles` for diagnostics.
    """

    def __init__(self, allowed: int | None = 0, what: str = "",
                 track=None):
        self.allowed = None if allowed is None else int(allowed)
        self.what = what
        self.track = list(track) if track else None
        self._start = 0
        self._track_start = 0

    def _tracked_programs(self) -> int:
        return sum(t.compiled_programs() for t in self.track)

    def __enter__(self) -> "CompileGuard":
        install_compile_listener()
        self._start = _count
        if self.track:
            self._track_start = self._tracked_programs()
        return self

    @property
    def backend_compiles(self) -> int:
        """Global XLA backend compilations inside the region."""
        return _count - self._start

    @property
    def count(self) -> int:
        if self.track:
            return self._tracked_programs() - self._track_start
        return self.backend_compiles

    def __exit__(self, exc_type, exc, tb) -> bool:
        if (exc_type is None and self.allowed is not None
                and self.count > self.allowed):
            label = f" in {self.what}" if self.what else ""
            raise RecompileError(
                f"{self.count} XLA compile(s){label} where at most "
                f"{self.allowed} allowed — the compile-once invariant is "
                f"broken (a traced shape/dtype/static arg is varying)")
        return False


def compile_guard(allowed: int | None = 0, what: str = "",
                  track=None) -> CompileGuard:
    """``with compile_guard(allowed=0, what="steady-state decode"): ...``"""
    return CompileGuard(allowed=allowed, what=what, track=track)
