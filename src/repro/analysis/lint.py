"""AST lint engine for the repo's DSD0xx invariants.

Usage::

    python -m repro.analysis.lint src [--baseline FILE] [--write-baseline]
                                      [--select DSD001,DSD003]

The engine parses every ``.py`` file under the given paths into a
:class:`Project` (module ASTs keyed by dotted module name, so rules can
resolve cross-module imports and jit-entry reachability), runs every
registered rule, and prints ``path:line:col: CODE message`` findings.

Exit status is nonzero iff any finding is not covered by the baseline
file.  Baselines fingerprint findings by (path, rule, stripped source
line, occurrence index) so they survive unrelated line churn; regenerate
with ``--write-baseline`` after auditing.

This module deliberately imports neither jax nor numpy: CI runs the lint
step before the heavyweight test lane.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import hashlib
import json
import sys
from pathlib import Path
from typing import Callable, Iterable, Iterator


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class ModuleInfo:
    path: Path
    name: str          # dotted module name relative to the scanned root
    tree: ast.Module
    source: str

    def source_line(self, lineno: int) -> str:
        lines = self.source.splitlines()
        if 1 <= lineno <= len(lines):
            return lines[lineno - 1]
        return ""


class Project:
    """All parsed modules of one lint run, indexed for cross-module lookup."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = {m.name: m for m in modules}

    def resolve_module(self, dotted: str) -> ModuleInfo | None:
        """Find a module by absolute dotted name, tolerating root prefixes.

        When the scan root is ``src`` the modules are named
        ``repro.core.engine``; when a caller imports ``repro.core.engine``
        that's an exact hit.  When the scan root is deeper (a fixture dir,
        ``src/repro``), fall back to unique-suffix matching.
        """
        if dotted in self.modules:
            return self.modules[dotted]
        hits = [m for name, m in self.modules.items()
                if name.endswith("." + dotted) or dotted.endswith("." + name)]
        if len(hits) == 1:
            return hits[0]
        return None


Rule = Callable[[Project], Iterable[Finding]]
_RULES: dict[str, Rule] = {}


def rule(code: str) -> Callable[[Rule], Rule]:
    def register(fn: Rule) -> Rule:
        if code in _RULES:
            raise ValueError(f"duplicate lint rule {code}")
        _RULES[code] = fn
        return fn
    return register


def registered_rules() -> dict[str, Rule]:
    from . import rules as _rules  # noqa: F401  (registration side effect)
    return dict(_RULES)


# ---------------------------------------------------------------------------
# project loading
# ---------------------------------------------------------------------------

def _module_name(root: Path, file: Path) -> str:
    rel = file.relative_to(root)
    parts = list(rel.with_suffix("").parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else root.resolve().name


def load_project(paths: Iterable[str | Path]) -> Project:
    modules: list[ModuleInfo] = []
    seen: set[Path] = set()
    for raw in paths:
        root = Path(raw)
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        base = root.parent if root.is_file() else root
        for file in files:
            file = file.resolve()
            if file in seen:
                continue
            seen.add(file)
            source = file.read_text()
            try:
                tree = ast.parse(source, filename=str(file))
            except SyntaxError as exc:  # surfaced as a finding, not a crash
                tree = ast.Module(body=[], type_ignores=[])
                tree._dsd_syntax_error = exc  # type: ignore[attr-defined]
            modules.append(ModuleInfo(
                path=file, name=_module_name(base.resolve(), file),
                tree=tree, source=source))
    return Project(modules)


def display_path(path: Path) -> str:
    try:
        return str(path.relative_to(Path.cwd()))
    except ValueError:
        return str(path)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def _fingerprints(findings: list[Finding], project: Project) -> list[str]:
    """Stable ids: hash of (path, rule, stripped line text, occurrence #)."""
    by_path = {display_path(m.path): m for m in project.modules.values()}
    counts: dict[tuple, int] = {}
    fps = []
    for f in findings:
        mod = by_path.get(f.path)
        text = mod.source_line(f.line).strip() if mod else ""
        key = (f.path, f.rule, text)
        n = counts.get(key, 0)
        counts[key] = n + 1
        raw = f"{f.path}|{f.rule}|{text}|{n}"
        fps.append(hashlib.sha1(raw.encode()).hexdigest()[:16])
    return fps


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def write_baseline(path: Path, findings: list[Finding], project: Project) -> None:
    payload = {
        "version": 1,
        "comment": "dsd-lint baseline; regenerate with "
                   "`python -m repro.analysis.lint src --write-baseline`",
        "fingerprints": sorted(set(_fingerprints(findings, project))),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


# ---------------------------------------------------------------------------
# runner + CLI
# ---------------------------------------------------------------------------

_NOQA = "# noqa"


def _suppressed(f: Finding, project: Project) -> bool:
    """`# noqa` (any rule) or `# noqa: DSD001[,DSD002]` on the finding's
    line suppresses it."""
    for mod in project.modules.values():
        if display_path(mod.path) == f.path:
            line = mod.source_line(f.line)
            idx = line.find(_NOQA)
            if idx < 0:
                return False
            tail = line[idx + len(_NOQA):].strip()
            if not tail.startswith(":"):
                return True
            codes = {c.strip() for c in tail[1:].split(",")}
            return f.rule in codes
    return False


def run_project(project: Project, select: set[str] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for mod in project.modules.values():
        err = getattr(mod.tree, "_dsd_syntax_error", None)
        if err is not None:
            findings.append(Finding(display_path(mod.path), err.lineno or 1,
                                    (err.offset or 1) - 1, "DSD000",
                                    f"syntax error: {err.msg}"))
    for code, fn in sorted(registered_rules().items()):
        if select and code not in select:
            continue
        findings.extend(f for f in fn(project)
                        if not _suppressed(f, project))
    return sorted(findings)


def run_paths(paths: Iterable[str | Path],
              select: set[str] | None = None) -> list[Finding]:
    return run_project(load_project(paths), select=select)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="DSD repo-invariant linter (rules DSD001..DSD005)")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--baseline", default=".dsd-lint-baseline.json",
                    help="baseline file of accepted findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as the baseline and exit 0")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run (default: all)")
    args = ap.parse_args(argv)

    select = set(args.select.split(",")) if args.select else None
    project = load_project(args.paths)
    findings = run_project(project, select=select)

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        write_baseline(baseline_path, findings, project)
        print(f"dsd-lint: wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    fps = _fingerprints(findings, project)
    fresh = [f for f, fp in zip(findings, fps) if fp not in baseline]
    suppressed = len(findings) - len(fresh)

    for f in fresh:
        print(f.format())
    tail = f" ({suppressed} baselined)" if suppressed else ""
    print(f"dsd-lint: {len(fresh)} finding(s) in "
          f"{len(project.modules)} module(s){tail}")
    return 1 if fresh else 0


if __name__ == "__main__":
    # under `python -m repro.analysis.lint` this file runs as __main__;
    # delegate to the canonical module so rules register into one registry
    from repro.analysis.lint import main as _main
    sys.exit(_main())
